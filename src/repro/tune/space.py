"""Candidate enumeration for every tunable Pallas kernel in the repo.

Each kernel family exposes a *config space*: the set of legal tiling /
factorization choices for a given (logical) input shape.  Legality encodes
the TPU constraints that used to be implicit in hand-picked constants:

  * lane (last) block dim a multiple of LANE (128),
  * sublane (second-to-last) a multiple of SUBLANE (8, f32),
  * the working set of all VMEM-resident blocks — double-buffered inputs/
    outputs plus scratch — under ``VMEM_BUDGET_BYTES`` (a conservative
    slice of the ~16 MiB/core VMEM so the pipeline can overlap DMA).

Configs are plain ``{str: int}`` dicts so they round-trip through the JSON
cache unchanged.  ``default_config`` reproduces the repo's legacy hardwired
constants (clamped to the shape exactly the way the kernels used to), so the
tuner always has the historical baseline in its candidate set.

Kernel names and their shape/config conventions:

  kernel             shape                 config keys
  -----------------  --------------------  -------------------------
  xcorr_offdiag      (n, d)                tile_n, tile_d
  cmatmul            (m, k, n)             tm, tn, tk
  ctwiddle           (n, d)                tn
  pmatmul            (m, k, n)             tm, tn, tk
  freq_outer         (f, k, n)             tk, tn
  freq_mat           (f, k, n, n2)         tk
  sumvec_fft_plan    (d,)                  dp, d1, d2   (dp > d => padded)
  grouped_block_plan (n, d)                b            (block DFT group size)
  paged_attention    (b, s, kv, hd)        page         (KV tokens per block)

``grouped_block_plan`` is a *plan* kernel like ``sumvec_fft_plan``: its
config is the grouped regularizer's block size b itself (searched over
``grouped_block_size_candidates`` instead of fixed by the caller), and the
pipeline it selects delegates all tiling to pmatmul/freq_outer/freq_mat.
NOTE: b is part of the LOSS definition — plan-tuning it is for perf studies
and serve probes where any legal b computes a valid health signal; training
configs that pin b for accuracy reasons must keep passing it explicitly.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

from repro.kernels.pallas_utils import LANE, SUBLANE, next_multiple

Config = Dict[str, int]
Shape = Tuple[int, ...]

VMEM_BYTES = 16 * 2**20
# Working-set ceiling for one kernel instance (inputs/outputs double-buffered
# + scratch).  3/4 of VMEM leaves room for compiler spills and semaphores.
VMEM_BUDGET_BYTES = 12 * 2**20

F32 = 4  # bytes; all kernels accumulate in f32

_SUBLANE_TILES = (8, 16, 32, 64, 128, 256, 512)
_LANE_TILES = (128, 256, 512, 1024)

KERNELS = (
    "xcorr_offdiag",
    "cmatmul",
    "ctwiddle",
    "pmatmul",
    "freq_outer",
    "freq_mat",
    "sumvec_fft_plan",
    "grouped_block_plan",
    "paged_attention",
)


def _tile_options(dim: int, unit: int, grid) -> List[int]:
    """Tile sizes from ``grid`` clamped to the padded extent of ``dim``."""
    cap = next_multiple(dim, unit)
    opts = sorted({min(t, cap) for t in grid})
    return [t for t in opts if t % unit == 0]


# ---------------------------------------------------------------------------
# Per-kernel VMEM working sets (bytes).  Factor 2 = double buffering.
# ---------------------------------------------------------------------------


def vmem_bytes(kernel: str, shape: Shape, cfg: Config) -> int:
    if kernel == "xcorr_offdiag":
        tn, td = cfg["tile_n"], cfg["tile_d"]
        return 2 * (2 * tn * td * F32) + td * td * F32
    if kernel == "cmatmul":
        tm, tn, tk = cfg["tm"], cfg["tn"], cfg["tk"]
        return 2 * (2 * tm * tk + 2 * tk * tn + 2 * tm * tn) * F32
    if kernel == "pmatmul":
        tm, tn, tk = cfg["tm"], cfg["tn"], cfg["tk"]
        return 2 * (tm * tk + tk * tn + tm * tn) * F32
    if kernel == "ctwiddle":
        tn = cfg["tn"]
        dp = next_multiple(shape[1], LANE)
        return 2 * (4 * tn * dp + 2 * dp) * F32
    if kernel == "freq_outer":
        tk, tn = cfg["tk"], cfg["tn"]
        npad = next_multiple(shape[2], LANE)
        return 2 * (tk * npad + tk * tn + npad * tn) * F32
    if kernel == "freq_mat":
        tk = cfg["tk"]
        npad = next_multiple(shape[2], LANE)
        n2pad = next_multiple(shape[3], LANE)
        return 2 * (tk * npad + npad * n2pad + tk * n2pad) * F32
    if kernel in ("sumvec_fft_plan", "grouped_block_plan"):
        # plans delegate all blocking to the matmul/twiddle kernels they
        # select; their own VMEM footprint is whatever those choose.
        return 0
    if kernel == "paged_attention":
        page = cfg["page"]
        kvp = next_multiple(shape[2], SUBLANE)
        hdp = next_multiple(shape[3], LANE)
        # q + out blocks are (kv, n_rep, hd); n_rep is not part of the cache
        # key, so charge one sublane tile of query heads per kv head.  One k
        # + one v page per grid step (all double-buffered), plus the
        # online-softmax scratch (acc, m, l).
        qo = SUBLANE * kvp * hdp
        return 2 * (2 * qo + 2 * page * kvp * hdp) * F32 + (qo + 2 * SUBLANE * kvp * LANE) * F32
    raise KeyError(kernel)


def is_legal(kernel: str, shape: Shape, cfg: Config) -> bool:
    """Lane/sublane alignment + VMEM budget for one candidate."""
    if kernel == "sumvec_fft_plan":
        (d,) = shape
        dp, d1, d2 = cfg["dp"], cfg["d1"], cfg["d2"]
        # enumeration canonicalizes to d1 <= d2, but any ordering is valid
        if d1 * d2 != dp or d1 < 1 or d2 < 1:
            return False
        # padded plans must be linear-correlation safe (no wraparound):
        return dp == d or dp >= 2 * d - 1
    if kernel == "grouped_block_plan":
        n, d = shape
        return 2 <= cfg["b"] <= d
    lane_keys = {
        "xcorr_offdiag": ("tile_d",),
        "cmatmul": ("tn", "tk"),
        "pmatmul": ("tn", "tk"),
        "ctwiddle": (),
        "freq_outer": ("tn",),
        "freq_mat": (),
        "paged_attention": (),
    }[kernel]
    sub_keys = {
        "xcorr_offdiag": ("tile_n",),
        "cmatmul": ("tm",),
        "pmatmul": ("tm",),
        "ctwiddle": ("tn",),
        "freq_outer": ("tk",),
        "freq_mat": ("tk",),
        "paged_attention": ("page",),
    }[kernel]
    for k in lane_keys:
        if cfg[k] <= 0 or cfg[k] % LANE:
            return False
    for k in sub_keys:
        if cfg[k] <= 0 or cfg[k] % SUBLANE:
            return False
    return vmem_bytes(kernel, shape, cfg) <= VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# Factorization helpers (sumvec_fft four-step plans)
# ---------------------------------------------------------------------------


def balanced_factors(x: int) -> Tuple[int, int]:
    """(d1, d2), d1 <= d2, d1 * d2 == x, d1 as large as possible.

    The single source of factorization policy: ``sumvec_fft.ops
    .choose_factors`` delegates here, as do plan defaults and candidates.
    """
    for d1 in range(int(math.isqrt(x)), 0, -1):
        if x % d1 == 0:
            return d1, x // d1
    return 1, x


def _divisor_factorizations(x: int, limit: int = 8) -> List[Tuple[int, int]]:
    out = []
    for d1 in range(int(math.isqrt(x)), 0, -1):
        if x % d1 == 0:
            out.append((d1, x // d1))
        if len(out) >= limit:
            break
    return out


def padded_plan_candidates(d: int, scan: int = 256, keep: int = 4) -> List[Config]:
    """Tile-friendly padded DFT lengths dp >= 2d - 1 with balanced factors.

    Zero-padding the feature axis to dp and folding the linear correlation
    back to d circular lags is exact (see sumvec_fft.ops), so any dp here is
    semantics-preserving; we scan a bounded window above 2d - 1 for highly
    composite lengths and keep the cheapest few by the four-step FLOP proxy
    dp * (d1 + d2).
    """
    lo = max(2 * d - 1, 2)
    scored = []
    for dp in range(lo, lo + scan):
        d1, d2 = balanced_factors(dp)
        if d1 < max(2, math.isqrt(dp) // 4):
            continue  # too lopsided to beat the direct DFT reliably
        scored.append((dp * (d1 + d2), {"dp": dp, "d1": d1, "d2": d2}))
    scored.sort(key=lambda t: (t[0], t[1]["dp"]))
    return [cfg for _, cfg in scored[:keep]]


# ---------------------------------------------------------------------------
# Candidate enumeration + defaults
# ---------------------------------------------------------------------------


def candidates(kernel: str, shape: Shape) -> List[Config]:
    """All legal configs for ``kernel`` at ``shape`` (default always included)."""
    out: List[Config] = []
    if kernel == "xcorr_offdiag":
        n, d = shape
        for td in _tile_options(d, LANE, _LANE_TILES):
            for tn in _tile_options(n, SUBLANE, _SUBLANE_TILES):
                out.append({"tile_n": tn, "tile_d": td})
    elif kernel in ("cmatmul", "pmatmul"):
        m, k, n = shape
        for tm in _tile_options(m, SUBLANE, _SUBLANE_TILES):
            for tn in _tile_options(n, LANE, _LANE_TILES):
                for tk in _tile_options(k, LANE, _LANE_TILES):
                    out.append({"tm": tm, "tn": tn, "tk": tk})
    elif kernel == "ctwiddle":
        n, d = shape
        for tn in _tile_options(n, SUBLANE, _SUBLANE_TILES):
            out.append({"tn": tn})
    elif kernel == "freq_outer":
        f, k, n = shape
        for tk in _tile_options(k, SUBLANE, _SUBLANE_TILES):
            for tn in _tile_options(next_multiple(n, LANE), LANE, _LANE_TILES):
                out.append({"tk": tk, "tn": tn})
    elif kernel == "freq_mat":
        f, k, n, n2 = shape
        for tk in _tile_options(k, SUBLANE, _SUBLANE_TILES):
            out.append({"tk": tk})
    elif kernel == "sumvec_fft_plan":
        (d,) = shape
        for d1, d2 in _divisor_factorizations(d):
            out.append({"dp": d, "d1": d1, "d2": d2})
        out.extend(padded_plan_candidates(d))
    elif kernel == "grouped_block_plan":
        n, d = shape
        out.extend({"b": b} for b in grouped_block_size_candidates(d))
    elif kernel == "paged_attention":
        b, s, kv, hd = shape
        for page in _tile_options(s, SUBLANE, _SUBLANE_TILES):
            out.append({"page": page})
    else:
        raise KeyError(kernel)
    default = default_config(kernel, shape)
    if default not in out:
        out.append(default)
    return [cfg for cfg in out if is_legal(kernel, shape, cfg)]


def default_config(kernel: str, shape: Shape) -> Config:
    """The repo's historical hardwired choice, clamped the way the kernels
    used to clamp it (``min(CONST, next_multiple(dim, unit))``)."""
    if kernel == "xcorr_offdiag":
        n, d = shape
        return {
            "tile_n": min(128, next_multiple(n, SUBLANE)),
            "tile_d": min(256, next_multiple(d, LANE)),
        }
    if kernel in ("cmatmul", "pmatmul"):
        m, k, n = shape
        return {
            "tm": min(128, next_multiple(m, SUBLANE)),
            "tn": min(128, next_multiple(n, LANE)),
            "tk": min(128, next_multiple(k, LANE)),
        }
    if kernel == "ctwiddle":
        n, d = shape
        return {"tn": min(128, next_multiple(n, SUBLANE))}
    if kernel == "freq_outer":
        f, k, n = shape
        return {
            "tk": min(128, next_multiple(k, SUBLANE)),
            "tn": min(128, next_multiple(n, LANE)),
        }
    if kernel == "freq_mat":
        f, k, n, n2 = shape
        return {"tk": min(128, next_multiple(k, SUBLANE))}
    if kernel == "sumvec_fft_plan":
        (d,) = shape
        d1, d2 = balanced_factors(d)
        return {"dp": d, "d1": d1, "d2": d2}
    if kernel == "grouped_block_plan":
        n, d = shape
        # the paper's Fig. 3 sweet spot: largest legal b <= 128 (one MXU
        # tile); mirrors grouped_sumvec.ops.auto_block_size, inlined to keep
        # space importable from the kernel modules
        return {"b": max(b for b in grouped_block_size_candidates(d) if b <= 128)}
    if kernel == "paged_attention":
        b, s, kv, hd = shape
        # vLLM's classic 16-token block, clamped to short contexts
        return {"page": min(16, next_multiple(s, SUBLANE))}
    raise KeyError(kernel)


def grouped_block_size_candidates(d: int) -> List[int]:
    """Legal grouped-regularizer block sizes b for width d: powers of two
    from 2 up to d, plus d itself (== ungrouped Eq. 6).  Consumed by
    benchmarks/bench_blocksize.py, the CLI pre-tuner, and the
    ``grouped_block_plan`` candidate space."""
    out = []
    b = 2
    while b < d:
        out.append(b)
        b *= 2
    out.append(d)
    return out
