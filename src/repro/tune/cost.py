"""Cost models for ranking kernel configs.

Three fidelity tiers, all deterministic on CPU/interpret:

  * ``analytic_cost``   — closed-form FLOPs / HBM-traffic / VMEM estimates
                          derived from the kernels' grid + BlockSpec algebra.
                          Instant; used by the implicit dispatch fallback.
  * ``compiled_cost``   — lower + compile the real kernel for the candidate
                          and read trip-exact FLOPs/bytes off the optimized
                          HLO via ``launch.hlo_cost.analyze_hlo`` ("dry"
                          mode: no execution, deterministic everywhere).
  * ``measured_time_us``— best-of-N wall clock of the jitted candidate
                          (optional refinement; non-deterministic, never the
                          primary key in dry mode).

Analytic ranking is a roofline scalar, not flops-lexicographic:
``max(flops / PEAK_FLOPS, hbm_bytes / HBM_BW) + grid_steps * step_overhead``
(flops and vmem as deterministic tiebreaks).  Padding FLOPs on the MXU are
nearly free while re-reads and per-grid-step dispatch are not — a
flops-first ordering would pick degenerate minimum-sublane tiles (tm = 8)
for any m a larger tile would pad, which is exactly backwards on hardware.
Constants mirror launch.hlo_cost's TPU v5e roofline (kept local: the
analytic tier must not import repro.launch).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax

from repro.kernels.pallas_utils import LANE, SUBLANE, next_multiple
from repro.tune.space import Config, Shape, vmem_bytes

F32 = 4
# TPU v5e roofline constants (see launch.hlo_cost; duplicated to keep the
# analytic dispatch tier free of the launch package)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
# charged per grid step: DMA descriptor / pipeline dispatch latency
GRID_STEP_OVERHEAD_S = 1e-6
# batch the plan cost model amortizes batch-independent stages over (the
# paper's SSL batch); plans are cached per d, so one representative n is used
NOMINAL_BATCH = 256


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def analytic_cost(kernel: str, shape: Shape, cfg: Config) -> Dict[str, float]:
    """Closed-form {flops, hbm_bytes, grid_steps, vmem_bytes} for a config."""
    if kernel == "xcorr_offdiag":
        n, d = shape
        tn, td = cfg["tile_n"], cfg["tile_d"]
        dp, npd = next_multiple(d, td), next_multiple(n, tn)
        grid = (dp // td) ** 2 * (npd // tn)
        flops = 2.0 * dp * dp * npd
        hbm = F32 * (2.0 * dp * dp * npd / td)  # both inputs, re-read per j/i
    elif kernel == "cmatmul":
        m, k, n = shape
        tm, tn, tk = cfg["tm"], cfg["tn"], cfg["tk"]
        mp, kp, npd = next_multiple(m, tm), next_multiple(k, tk), next_multiple(n, tn)
        grid = (mp // tm) * (npd // tn) * (kp // tk)
        flops = 8.0 * mp * npd * kp  # 4 real dots
        hbm = F32 * (2.0 * mp * kp * (npd / tn) + 2.0 * kp * npd * (mp / tm) + 2.0 * mp * npd)
    elif kernel == "pmatmul":
        m, k, n = shape
        tm, tn, tk = cfg["tm"], cfg["tn"], cfg["tk"]
        mp, kp, npd = next_multiple(m, tm), next_multiple(k, tk), next_multiple(n, tn)
        grid = (mp // tm) * (npd // tn) * (kp // tk)
        flops = 2.0 * mp * npd * kp
        hbm = F32 * (mp * kp * (npd / tn) + kp * npd * (mp / tm) + mp * npd)
    elif kernel == "ctwiddle":
        n, d = shape
        tn = cfg["tn"]
        dp, npd = next_multiple(d, LANE), next_multiple(n, tn)
        grid = npd // tn
        flops = 6.0 * npd * dp
        hbm = F32 * (4.0 * npd * dp + 2.0 * dp * grid)
    elif kernel == "freq_outer":
        f, k, n = shape
        tk, tn = cfg["tk"], cfg["tn"]
        npad = next_multiple(n, LANE)
        kp = next_multiple(k, tk)
        grid = f * (npad // tn) * (kp // tk)
        flops = 2.0 * f * npad * npad * kp
        hbm = F32 * f * (kp * npad * (npad / tn) + kp * npad + npad * npad)
    elif kernel == "freq_mat":
        f, k, n, n2 = shape
        tk = cfg["tk"]
        npad, n2pad = next_multiple(n, LANE), next_multiple(n2, LANE)
        kp = next_multiple(k, tk)
        grid = f * (kp // tk)
        flops = 2.0 * f * kp * npad * n2pad
        hbm = F32 * f * (kp * npad + npad * n2pad * (kp / tk) + kp * n2pad)
    elif kernel == "paged_attention":
        b, s, h, hd = shape
        page = cfg["page"]
        hp = next_multiple(h, SUBLANE)
        hdp = next_multiple(hd, LANE)
        nb = _cdiv(s, page)
        sp = nb * page
        grid = b * nb
        flops = 4.0 * b * sp * hp * hdp  # qk + pv per context token
        # k/v pages stream once; q and the revisited output block re-read per page
        hbm = F32 * b * (2.0 * sp * hp * hdp + 2.0 * nb * hp * hdp)
    elif kernel == "grouped_block_plan":
        n, d = shape
        b = cfg["b"]
        nb = _cdiv(d, b)
        nf = b // 2 + 1
        # block DFT forward, both views: (n*nb, b) @ (b, 2*nf) per view
        flops = 2.0 * 2.0 * (n * nb) * b * (2.0 * nf)
        hbm = F32 * 2.0 * (n * nb * b + b * 2 * nf + n * nb * 2 * nf)
        # pairwise frequency-outer stage on the LANE-padded group axis —
        # tiny nb pays full-tile padding, which is exactly what makes very
        # small b lose despite its lower DFT flops
        npad = next_multiple(nb, LANE)
        flops += 2.0 * nf * (2.0 * n) * npad * npad
        hbm += F32 * nf * (2.0 * n * npad + npad * npad)
        grid = _cdiv(n * nb, SUBLANE) + nf
    elif kernel == "sumvec_fft_plan":
        (d,) = shape
        dp, d1, d2 = cfg["dp"], cfg["d1"], cfg["d2"]
        padded = dp > d
        # forward runs per batch row (both views: two cmatmul stages + one
        # twiddle); the inverse runs ONCE on the batch-reduced accumulator,
        # so it is amortized over the batch — charge it against a nominal
        # training batch, not per row, or padded plans look ~n times worse
        # than they are.
        fwd = 16.0 * dp * (d1 + d2) + 12.0 * dp
        inv = 8.0 * dp * (d1 + d2) + 6.0 * dp
        flops = NOMINAL_BATCH * fwd + (inv if padded else 0.0)
        # basis materialization + one streaming pass per stage
        hbm = F32 * (6.0 * dp * NOMINAL_BATCH + 2.0 * (d1 * d1 + d2 * d2))
        grid = _cdiv(dp, LANE)
    else:
        raise KeyError(kernel)
    return {
        "flops": float(flops),
        "hbm_bytes": float(hbm),
        "grid_steps": float(grid),
        "vmem_bytes": float(vmem_bytes(kernel, shape, cfg)),
    }


def rank_key(cost: Dict[str, float], kernel: str = "") -> Tuple[float, float, float]:
    if kernel in ("sumvec_fft_plan", "grouped_block_plan"):
        # plans trade padding against factor balance (or DFT work against
        # pairwise-stage padding) — arithmetic IS the tradeoff, and per-row
        # costs are too small for the roofline's grid term to mean anything.
        # Rank flops-first.
        return (cost["flops"], cost["hbm_bytes"], cost.get("vmem_bytes", 0.0))
    roofline_s = (
        max(cost["flops"] / PEAK_FLOPS, cost["hbm_bytes"] / HBM_BW)
        + cost.get("grid_steps", 0.0) * GRID_STEP_OVERHEAD_S
    )
    return (roofline_s, cost["flops"], cost.get("vmem_bytes", 0.0))


# ---------------------------------------------------------------------------
# Compiled ("dry") and measured tiers
# ---------------------------------------------------------------------------


def compiled_with_cost(fn: Callable, *shape_args):
    """(compiled executable, trip-exact cost dict) — one compilation serves
    both the dry ranking and measure-mode timing."""
    # imported here, not at module top: the analytic tier (what kernels use
    # implicitly) must not drag repro.launch into the hot dispatch path.
    from repro.launch.hlo_cost import analyze_hlo

    compiled = jax.jit(fn).lower(*shape_args).compile()
    a = analyze_hlo(compiled.as_text())
    cost = {"flops": a.flops, "hbm_bytes": a.hbm_bytes, "grid_steps": 0.0, "vmem_bytes": 0.0}
    return compiled, cost


def compiled_cost(fn: Callable, *shape_args) -> Dict[str, float]:
    """Trip-exact FLOPs/bytes of the compiled single-device graph (no run)."""
    return compiled_with_cost(fn, *shape_args)[1]


def measured_time_us(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N wall time in microseconds (blocks on results).

    ``fn`` must already be jitted or AOT-compiled — this times exactly the
    callable it is given, so the tuner can reuse the executable it already
    compiled for the dry ranking instead of compiling twice.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6
