"""The tuner: search a kernel's config space and persist the winner.

Modes:
  * ``analytic`` — rank by the closed-form model only.  Instant.
  * ``dry``      — compile each candidate (top-K by analytic pre-rank) and
                   rank by trip-exact HLO FLOPs, then HBM bytes.  No kernel
                   is executed, so this is deterministic on CPU/interpret
                   and on real hardware alike.
  * ``measure``  — additionally run each compiled candidate and rank by
                   best-of-N wall time (compiled FLOPs as tiebreak).

In ``dry``/``measure`` mode the legacy default config is always evaluated,
and ``guard_default=True`` (the default) only accepts a winner that is no
worse than the default on BOTH compiled FLOPs and bytes — the tuner can
refuse to move, it can never regress the baseline.

Trace-time caveat: kernel wrappers resolve configs when jit TRACES them, so
a wrapper already traced in this process keeps its old config until its jit
cache entry is evicted (e.g. new shape) or the process restarts.  Pre-tune
before the first training step — the ``repro.tune.cli`` workflow — or tune
in a separate process and let the JSON cache carry the result.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.tune import cache as _cache
from repro.tune import cost as _cost
from repro.tune import dispatch as _dispatch
from repro.tune import space as _space

Config = Dict[str, int]


@dataclasses.dataclass
class Candidate:
    config: Config
    cost: Dict[str, float]
    time_us: Optional[float] = None


@dataclasses.dataclass
class TuneResult:
    kernel: str
    shape: Tuple[int, ...]
    dtype: str
    backend: str
    mode: str
    best: Config
    default: Config
    candidates: List[Candidate]

    def candidate_for(self, config: Config) -> Candidate:
        for c in self.candidates:
            if c.config == config:
                return c
        raise KeyError(config)


# ---------------------------------------------------------------------------
# Builders: (shape, config) -> (fn, concrete example args) for compile/run.
# Kernel modules are imported lazily to keep tune importable from them.
# ---------------------------------------------------------------------------


def _ones(*shapes):
    return [jnp.ones(s, jnp.float32) for s in shapes]


def _build(kernel: str, shape: Tuple[int, ...], cfg: Config) -> Tuple[Callable, list]:
    if kernel == "xcorr_offdiag":
        from repro.kernels.xcorr_offdiag.kernel import off_diagonal_sq_sum_raw

        n, d = shape
        fn = lambda a, b: off_diagonal_sq_sum_raw(
            a, b, tile_d=cfg["tile_d"], tile_n=cfg["tile_n"]
        )
        return fn, _ones((n, d), (n, d))
    if kernel == "cmatmul":
        from repro.kernels.sumvec_fft.kernel import _cmatmul_raw

        m, k, n = shape
        fn = lambda ar, ai, br, bi: _cmatmul_raw(
            ar, ai, br, bi, tm=cfg["tm"], tn=cfg["tn"], tk=cfg["tk"]
        )
        return fn, _ones((m, k), (m, k), (k, n), (k, n))
    if kernel == "ctwiddle":
        from repro.kernels.sumvec_fft.kernel import _ctwiddle_raw

        n, d = shape
        fn = lambda xr, xi, wr, wi: _ctwiddle_raw(xr, xi, wr, wi, tn=cfg["tn"])
        return fn, _ones((n, d), (n, d), (d,), (d,))
    if kernel == "pmatmul":
        from repro.kernels.grouped_sumvec.kernel import _pmatmul_raw

        m, k, n = shape
        fn = lambda a, b: _pmatmul_raw(a, b, tm=cfg["tm"], tn=cfg["tn"], tk=cfg["tk"])
        return fn, _ones((m, k), (k, n))
    if kernel == "freq_outer":
        from repro.kernels.grouped_sumvec.kernel import _freq_outer_raw

        f, k, n = shape
        fn = lambda a, b: _freq_outer_raw(a, b, tk=cfg["tk"], tn=cfg["tn"])
        return fn, _ones((f, k, n), (f, k, n))
    if kernel == "freq_mat":
        from repro.kernels.grouped_sumvec.kernel import _freq_mat_raw

        f, k, n, n2 = shape
        fn = lambda a, m_: _freq_mat_raw(a, m_, tk=cfg["tk"])
        return fn, _ones((f, k, n), (f, n, n2))
    if kernel == "paged_attention":
        from repro.kernels.paged_attention.ops import paged_decode_attention_raw

        b, s, h, hd = shape
        page = cfg["page"]
        nb = -(-s // page)
        bt = jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
        lens = jnp.full((b,), s, jnp.int32)
        fn = lambda q, kp, vp: paged_decode_attention_raw(
            q, kp, vp, bt, lens, scale=1.0 / max(hd, 1) ** 0.5
        )
        return fn, _ones((b, h, hd), (b * nb, page, h, hd), (b * nb, page, h, hd))
    if kernel == "grouped_block_plan":
        from repro.kernels.grouped_sumvec import ops as gops

        n, d = shape
        fn = lambda a, b_: gops.r_sum_kernel(a, b_, block_size=cfg["b"], q=2)
        return fn, _ones((n, d), (n, d))
    if kernel == "sumvec_fft_plan":
        from repro.kernels.sumvec_fft import ops as fops

        (d,) = shape
        plan = fops.FFTPlan(d=d, dp=cfg["dp"], d1=cfg["d1"], d2=cfg["d2"])
        # evaluate at a realistic batch: the inverse stage runs once on the
        # batch-reduced accumulator, so a tiny n would overweight it
        n = _cost.NOMINAL_BATCH
        fn = lambda a, b: fops._r_sum_impl(a, b, q=2, s=1.0, plan=plan)
        return fn, _ones((n, d), (n, d))
    raise KeyError(kernel)


def _compiled_key(cost: Dict[str, float]) -> Tuple[float, float]:
    return (cost["flops"], cost["hbm_bytes"])


def tune(
    kernel: str,
    shape,
    dtype=jnp.float32,
    *,
    mode: str = "dry",
    max_candidates: int = 6,
    guard_default: bool = True,
    persist: bool = True,
    repeats: int = 3,
    backend: Optional[str] = None,
) -> TuneResult:
    """Search ``kernel``'s config space at ``shape``; install + persist the best."""
    assert mode in ("analytic", "dry", "measure"), mode
    backend = backend or jax.default_backend()
    canon = _dispatch.canonical_shape(kernel, shape)
    dtype_s = jnp.dtype(dtype).name
    default = _space.default_config(kernel, canon)

    cands = _space.candidates(kernel, canon)
    cands.sort(key=lambda c: _cost.rank_key(_cost.analytic_cost(kernel, canon, c), kernel))
    if max_candidates and len(cands) > max_candidates:
        cands = cands[:max_candidates]
    if default not in cands:
        cands.append(default)

    evaluated: List[Candidate] = []
    if mode == "analytic":
        for cfg in cands:
            evaluated.append(Candidate(cfg, _cost.analytic_cost(kernel, canon, cfg)))
        best = min(evaluated, key=lambda c: _cost.rank_key(c.cost, kernel)).config
    else:
        for cfg in cands:
            fn, args = _build(kernel, canon, cfg)
            compiled, c = _cost.compiled_with_cost(fn, *args)
            t = (
                _cost.measured_time_us(compiled, *args, repeats=repeats)
                if mode == "measure"
                else None
            )
            evaluated.append(Candidate(cfg, c, t))
        default_cand = next(c for c in evaluated if c.config == default)
        pool = evaluated
        if guard_default:
            pool = [
                c
                for c in evaluated
                if c.cost["flops"] <= default_cand.cost["flops"]
                and c.cost["hbm_bytes"] <= default_cand.cost["hbm_bytes"]
            ] or [default_cand]
        if mode == "measure":
            best = min(pool, key=lambda c: (c.time_us, *_compiled_key(c.cost))).config
        else:
            best = min(pool, key=lambda c: _compiled_key(c.cost)).config

    _dispatch.record(kernel, canon, best, dtype, backend=backend)
    if persist:
        best_cand = next(c for c in evaluated if c.config == best)
        cost_rec = dict(best_cand.cost)
        if best_cand.time_us is not None:
            cost_rec["time_us"] = best_cand.time_us
        _cache.store(kernel, canon, dtype_s, backend, best, source=mode, cost=cost_rec)
    return TuneResult(
        kernel=kernel,
        shape=canon,
        dtype=dtype_s,
        backend=backend,
        mode=mode,
        best=dict(best),
        default=default,
        candidates=evaluated,
    )
