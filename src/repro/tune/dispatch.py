"""Kernel-config dispatch: the one place call sites get their tiling from.

``best_config(kernel, shape)`` resolves, in precedence order:

  1. an explicit override installed with ``override(...)`` / ``set_override``
     (tests and benchmarks pin configs without touching the cache),
  2. the in-process memo (one search per (kernel, shape, dtype, backend)
     per process — a cache hit never re-searches),
  3. the persistent JSON cache (written by the CLI pre-tuner or by
     ``tuner.tune(persist=True)``),
  4. a deterministic analytic search over ``space.candidates`` ranked by
     ``cost.analytic_cost`` (instant; memoized but not persisted, so the
     on-disk cache only ever contains deliberately tuned entries).

All resolution happens at trace time with concrete Python ints, so jitted
wrappers pay nothing at execution time.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.pallas_utils import LANE, SUBLANE, next_multiple
from repro.tune import cache as _cache
from repro.tune import cost as _cost
from repro.tune import space as _space

Config = Dict[str, int]

_lock = threading.Lock()
_MEMO: Dict[Tuple, Config] = {}
_OVERRIDES: Dict[str, list] = {}

# shape-canonicalization units per axis, by kernel (None = semantic, no pad)
_CANON_UNITS = {
    "xcorr_offdiag": (SUBLANE, LANE),
    "cmatmul": (SUBLANE, LANE, LANE),
    "pmatmul": (SUBLANE, LANE, LANE),
    "ctwiddle": (SUBLANE, LANE),
    "freq_outer": (None, SUBLANE, LANE),
    "freq_mat": (None, SUBLANE, LANE, LANE),
    "sumvec_fft_plan": (None,),
    "grouped_block_plan": (None, None),
    "paged_attention": (None, SUBLANE, SUBLANE, LANE),
}


def canonical_shape(kernel: str, shape) -> Tuple[int, ...]:
    """The padded shape used as cache key (all configs clamp identically on
    it, so logically-distinct shapes that tile the same share one entry)."""
    units = _CANON_UNITS[kernel]
    assert len(units) == len(shape), (kernel, shape)
    return tuple(
        int(s) if u is None else next_multiple(int(s), u) for s, u in zip(shape, units)
    )


def _dtype_str(dtype) -> str:
    return jnp.dtype(dtype).name


def _analytic_search(kernel: str, shape: Tuple[int, ...]) -> Config:
    cands = _space.candidates(kernel, shape)
    if not cands:
        # Some shapes have a config-independent VMEM term that alone busts
        # the budget (e.g. freq_mat's full (npad, n2pad) operand block), so
        # no candidate is "legal".  These shapes always ran with the clamped
        # hardwired tiles before tuning existed — keep running them.
        return _space.default_config(kernel, shape)
    return min(
        cands, key=lambda c: _cost.rank_key(_cost.analytic_cost(kernel, shape, c), kernel)
    )


def best_config(
    kernel: str,
    shape,
    dtype=jnp.float32,
    *,
    backend: Optional[str] = None,
) -> Config:
    """The config every kernel wrapper consults when given no explicit tiles."""
    with _lock:
        stack = _OVERRIDES.get(kernel)
        params = dict(stack[-1]) if stack else None
    canon = canonical_shape(kernel, shape)
    if params is not None:
        merged = {**_space.default_config(kernel, canon), **params}
        if kernel == "sumvec_fft_plan":
            # plan keys are jointly constrained (dp == d1 * d2, dp == d or
            # dp >= 2d - 1); complete a partial override instead of handing
            # back an inconsistent merge, and reject the unsatisfiable ones
            # here with a message rather than deep in FFTPlan.
            has_d1, has_d2 = "d1" in params, "d2" in params
            if has_d1 and has_d2:
                if "dp" in params and params["dp"] != params["d1"] * params["d2"]:
                    raise ValueError(
                        f"sumvec_fft_plan override {params}: dp != d1 * d2"
                    )
                merged["dp"] = merged["d1"] * merged["d2"]
            elif has_d1 or has_d2:
                # one factor pinned: complete against the (possibly also
                # pinned) dp, never silently drop the pinned factor
                given = params["d1"] if has_d1 else params["d2"]
                if given <= 0 or merged["dp"] % given:
                    raise ValueError(
                        f"sumvec_fft_plan override {params} does not divide dp={merged['dp']}"
                    )
                other = merged["dp"] // given
                merged["d1"], merged["d2"] = (given, other) if has_d1 else (other, given)
            elif "dp" in params:
                merged["d1"], merged["d2"] = _space.balanced_factors(merged["dp"])
            if not _space.is_legal(kernel, canon, merged):
                raise ValueError(
                    f"sumvec_fft_plan override {params} is inconsistent at d={canon[0]}: {merged}"
                )
        return merged
    backend = backend or jax.default_backend()
    key = (kernel, canon, _dtype_str(dtype), backend)
    with _lock:
        hit = _MEMO.get(key)
    if hit is not None:
        return dict(hit)
    entry = _cache.lookup(kernel, canon, _dtype_str(dtype), backend)
    try:
        legal = entry is not None and _space.is_legal(kernel, canon, entry["config"])
    except (KeyError, TypeError):
        legal = False  # config with missing/renamed keys == cache miss
    if legal:
        cfg = entry["config"]
    else:
        cfg = _analytic_search(kernel, canon)
    with _lock:
        _MEMO[key] = dict(cfg)
    return dict(cfg)


def best_impl(op: str, *, backend: Optional[str] = None) -> str:
    """Implementation choice for ops with a jnp and a Pallas route.

    The Pallas kernels target the TPU MXU; under the CPU interpreter (and on
    backends Mosaic does not serve) the pure-jnp FFT route wins, so that is
    the deterministic analytic answer.  Overridable like any kernel via
    ``override(op, impl=...)``.

    Known limit: routing keys on the PROCESS default backend, not the device
    a particular computation is placed on — a CPU-placed loss inside a TPU
    process still routes to Pallas.  Pass ``impl="jnp"`` explicitly (or use
    ``override``) for cross-device debug/validation passes.
    """
    with _lock:
        stack = _OVERRIDES.get(op)
        pinned = stack[-1].get("impl") if stack else None
    if pinned is not None:
        return str(pinned)
    backend = backend or jax.default_backend()
    return "pallas" if backend == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Overrides + cache control
# ---------------------------------------------------------------------------


def set_override(kernel: str, **params) -> None:
    with _lock:
        _OVERRIDES.setdefault(kernel, []).append(dict(params))


def clear_override(kernel: str) -> None:
    with _lock:
        stack = _OVERRIDES.get(kernel)
        if stack:
            stack.pop()
        if not stack:
            _OVERRIDES.pop(kernel, None)


@contextlib.contextmanager
def override(kernel: str, **params):
    """Pin (part of) a kernel's config; beats every cache tier while active.

    Note: jitted wrappers resolve configs at trace time — an override only
    affects computations traced while it is active.
    """
    set_override(kernel, **params)
    try:
        yield
    finally:
        clear_override(kernel)


def clear_memory_cache() -> None:
    with _lock:
        _MEMO.clear()


def record(kernel: str, shape, config: Config, dtype=jnp.float32, *, backend: Optional[str] = None) -> None:
    """Install a searched config into the in-process memo (tuner hook)."""
    backend = backend or jax.default_backend()
    key = (kernel, canonical_shape(kernel, shape), _dtype_str(dtype), backend)
    with _lock:
        _MEMO[key] = dict(config)
