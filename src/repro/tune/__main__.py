"""``python -m repro.tune`` == ``python -m repro.tune.cli``."""

import sys

from repro.tune.cli import main

sys.exit(main())
