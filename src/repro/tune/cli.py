"""Offline pre-tuner: ``python -m repro.tune.cli --dry --arch ssl-paper``.

Derives the hot kernel shapes of an architecture config (batch x projector
widths, the four-step inner matmuls from the tuned FFT plan, the grouped
pipeline at the paper's best block size), tunes each, and persists the
winners to the JSON cache so training jobs start with a warm cache.

    python -m repro.tune.cli --dry --arch ssl-paper        # HLO-ranked, deterministic
    python -m repro.tune.cli --measure --arch ssl-paper    # wall-time ranked
    python -m repro.tune.cli --analytic --shape 256x2048   # instant, model-only
    python -m repro.tune.cli --dry --serve --shape 64x2048 # serve bucket ladder,
                                                           # forward-only shapes
"""

from __future__ import annotations

import argparse
import math
import os
import sys
from typing import List, Tuple

ARCHS = {
    "ssl-paper": "repro.configs.ssl_paper",
}

Job = Tuple[str, Tuple[int, ...]]


def arch_shapes(name: str) -> List[Tuple[int, int]]:
    """(batch, width) pairs for a registered architecture config."""
    import importlib

    mod = importlib.import_module(ARCHS[name])
    cfg = mod.config()
    n = int(cfg.batch_size)
    widths = sorted({int(w) for w in cfg.projector_widths})
    return [(n, d) for d in widths]


def jobs_for(n: int, d: int, block_size=None, forward_only=False, **tune_kw):
    """All tunable kernel shapes reached from one (n, d) regularizer call,
    forward AND backward pass (training dispatches the vjp shapes too).

    ``block_size``: the grouped-regularizer b the training config will use —
    pass the real one, or the grouped shapes warmed here won't match runtime
    dispatch.  With ``block_size=None`` the pre-tuner SEARCHES b itself: the
    ``grouped_block_plan`` space enumerates every legal candidate
    (``grouped_block_size_candidates``) and the winner — not a fixed paper
    constant — drives the derived grouped shapes.  b is part of the loss
    definition, so accuracy-pinned training configs should keep passing it.
    ``forward_only``: drop the vjp shapes — the serve path (inference probes)
    never differentiates, so pre-tuning them would warm dead entries.

    The four-step inner matmul shapes depend on the FFT plan (and the grouped
    shapes on b), so both plans are tuned here first and the derived shapes
    read off the winners.  Returns ([plan TuneResults], remaining jobs).
    """
    from repro import tune

    plans = [tune.tune("sumvec_fft_plan", (d,), **tune_kw)]
    dp, d1, d2 = (plans[0].best[k] for k in ("dp", "d1", "d2"))
    if block_size:
        b = min(int(block_size), d)
    else:
        plans.append(tune.tune("grouped_block_plan", (n, d), **tune_kw))
        b = int(plans[-1].best["b"])
    nb = math.ceil(d / b)
    nf = b // 2 + 1
    jobs = [
        ("xcorr_offdiag", (n, d)),
        # four-step forward: step-1/step-3 complex matmuls + twiddle
        ("cmatmul", (n * d2, d1, d1)),
        ("cmatmul", (n * d1, d2, d2)),
        ("ctwiddle", (n, dp)),
        # inverse four-step (padded plans and q = 1): batch-1 accumulator
        ("cmatmul", (d1, d2, d2)),
        ("cmatmul", (d2, d1, d1)),
        ("ctwiddle", (1, dp)),
        # grouped pipeline: block DFT fwd + pairwise stage
        ("pmatmul", (n * nb, b, 2 * nf)),
        ("pmatmul", (nb * nb, nf, b)),  # q = 1 synthesis
        ("freq_outer", (nf, 2 * n, nb)),
        ("freq_mat", (nf, 2 * n, nb, nb)),
    ]
    if not forward_only:
        jobs += [
            # four-step vjp: dB = A^H @ g shapes from _cmm_bwd
            ("cmatmul", (d1, n * d2, d1)),
            ("cmatmul", (d2, n * d1, d2)),
            # grouped block-DFT vjp pair
            ("pmatmul", (n * nb, 2 * nf, b)),
            ("pmatmul", (b, n * nb, 2 * nf)),
        ]
    # distinct canonical shapes only (small d collapses several of these)
    seen, uniq = set(), []
    for kernel, shape in jobs:
        key = (kernel, tune.canonical_shape(kernel, shape))
        if key not in seen:
            seen.add(key)
            uniq.append((kernel, shape))
    return plans, uniq


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.tune.cli", description=__doc__)
    p.add_argument("--arch", choices=sorted(ARCHS), help="architecture config to pre-tune")
    p.add_argument(
        "--shape",
        action="append",
        default=[],
        metavar="NxD",
        help="explicit (batch x width) shape, repeatable (e.g. 256x2048)",
    )
    mode = p.add_mutually_exclusive_group()
    mode.add_argument("--dry", action="store_true", help="rank by compiled HLO cost (default)")
    mode.add_argument("--measure", action="store_true", help="rank by measured wall time")
    mode.add_argument("--analytic", action="store_true", help="rank by the closed-form model only")
    p.add_argument("--max-candidates", type=int, default=6, help="compile/run at most K candidates")
    p.add_argument(
        "--block-size",
        type=int,
        help="grouped-regularizer b your training config uses (default: "
        "search the grouped_block_plan candidate space for it)",
    )
    p.add_argument(
        "--serve",
        action="store_true",
        help="pre-tune the SERVE bucket shapes instead: expand each (n, d) "
        "into the micro-batcher's bucket ladder (align .. n rows, width d) "
        "and tune forward-only (the inference probes never differentiate)",
    )
    p.add_argument(
        "--serve-align",
        type=int,
        default=None,
        help="bucket granularity for --serve (default: the f32 sublane tile)",
    )
    p.add_argument(
        "--data-parallel",
        type=int,
        default=1,
        help="batch-shard count: tune the SHARD-LOCAL rows (n / data_parallel) "
        "the decorr engine dispatches inside shard_map",
    )
    p.add_argument(
        "--model-parallel",
        type=int,
        default=1,
        help="feature-shard count for the engine's tp mode: the regularizer "
        "runs on the all_to_all-transposed (n / (dp * mp), d) rows",
    )
    p.add_argument(
        "--distributed",
        default=None,
        choices=["local", "global", "tp"],
        help="engine mode the shard-local shapes are for (default: tp when "
        "--model-parallel > 1, else global — only tp divides rows by mp)",
    )
    p.add_argument("--cache-dir", help="override the JSON cache directory (REPRO_TUNE_CACHE)")
    p.add_argument("--no-persist", action="store_true", help="search but do not write the cache")
    p.add_argument("-v", "--verbose", action="store_true")
    args = p.parse_args(argv)

    if args.cache_dir:
        os.environ["REPRO_TUNE_CACHE"] = args.cache_dir
    mode_s = "measure" if args.measure else "analytic" if args.analytic else "dry"

    shapes: List[Tuple[int, int]] = []
    for spec in args.shape:
        n_s, _, d_s = spec.lower().partition("x")
        try:
            shapes.append((int(n_s), int(d_s)))
        except ValueError:
            p.error(f"--shape wants NxD (e.g. 256x2048), got {spec!r}")
    if args.arch:
        shapes.extend(arch_shapes(args.arch))
    if not shapes:
        p.error("nothing to tune: pass --arch and/or --shape NxD")
    if args.serve:
        # one job per (bucket, width): every compiled variant the serving
        # engine's bucket ladder can dispatch, mirroring ServeEngine.warmup.
        from repro.serve.buckets import BucketPolicy, bucket_shapes

        expanded = []
        for n, d in shapes:
            policy = BucketPolicy(
                max_batch=n, align=args.serve_align or BucketPolicy().align
            )
            expanded.extend(bucket_shapes(policy, d))
        shapes = sorted(set(expanded))
    if args.data_parallel > 1 or args.model_parallel > 1:
        # mirror repro.decorr.warmup.shard_local_shape: model_parallel only
        # shrinks the rows the kernels see in the engine's tp mode.
        from repro.decorr import shard_local_shape
        from repro.decorr.config import DecorrConfig

        dist = args.distributed or ("tp" if args.model_parallel > 1 else "global")
        cfg = DecorrConfig(distributed=dist)
        shapes = [
            shard_local_shape(
                n, d, cfg,
                data_parallel=args.data_parallel,
                model_parallel=args.model_parallel,
            )
            for n, d in shapes
        ]

    from repro import tune
    from repro.tune import cache as tcache

    tune_kw = dict(
        mode=mode_s, max_candidates=args.max_candidates, persist=not args.no_persist
    )
    def report(res):
        moved = "tuned" if res.best != res.default else "kept default"
        line = f"{res.kernel:>16} {'x'.join(map(str, res.shape)):>18}  {moved}: {res.best}"
        if args.verbose:
            for c in sorted(res.candidates, key=lambda c: c.cost["flops"]):
                line += f"\n{'':>38}{c.config}  flops={c.cost['flops']:.3e} bytes={c.cost['hbm_bytes']:.3e}"
        print(line, flush=True)

    n_jobs = 0
    for n, d in shapes:
        plans, jobs = jobs_for(
            n, d, block_size=args.block_size, forward_only=args.serve, **tune_kw
        )
        for plan_result in plans:
            report(plan_result)
            n_jobs += 1
        for kernel, shape in jobs:
            res = tune.tune(kernel, shape, **tune_kw)
            n_jobs += 1
            report(res)
    where = tcache.cache_dir() if not args.no_persist else "(not persisted)"
    print(f"# tuned {n_jobs} kernel shapes in {mode_s} mode -> {where}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
