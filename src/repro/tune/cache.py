"""Persistent JSON cache for tuned kernel configs.

One file per JAX backend under the cache directory::

    <cache_dir>/<backend>.json
    {"schema": 1, "entries": {"<kernel>|<shape>|<dtype>": {"config": {...},
                                                           "source": "...",
                                                           "cost": {...}}}}

Cache directory resolution order:
  1. ``REPRO_TUNE_CACHE`` environment variable,
  2. ``~/.cache/repro-tune``.

Entries are keyed by (kernel name, canonically padded shape, dtype); the
backend lives in the filename so a cache written on TPU never leaks onto a
CPU run.  A schema-version mismatch invalidates the whole file (the entry
semantics may have changed), and all I/O failures degrade to a cache miss —
tuning never takes a training job down.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
from pathlib import Path
from typing import Dict, Optional

SCHEMA_VERSION = 1

_ENV_VAR = "REPRO_TUNE_CACHE"


def cache_dir() -> Path:
    env = os.environ.get(_ENV_VAR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-tune"


def _backend_path(backend: str, directory: Optional[Path] = None) -> Path:
    return (directory or cache_dir()) / f"{backend}.json"


def entry_key(kernel: str, shape, dtype: str) -> str:
    return f"{kernel}|{'x'.join(str(int(s)) for s in shape)}|{dtype}"


def load_all(backend: str, directory: Optional[Path] = None) -> Dict[str, dict]:
    """All entries for a backend; {} on missing file, bad JSON, or schema skew."""
    try:
        with open(_backend_path(backend, directory)) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict) or data.get("schema") != SCHEMA_VERSION:
        return {}
    entries = data.get("entries")
    return entries if isinstance(entries, dict) else {}


def lookup(
    kernel: str, shape, dtype: str, backend: str, directory: Optional[Path] = None
) -> Optional[dict]:
    """The cached entry ({"config", "source", "cost"}) or None."""
    entry = load_all(backend, directory).get(entry_key(kernel, shape, dtype))
    if isinstance(entry, dict) and isinstance(entry.get("config"), dict):
        return entry
    return None


@contextlib.contextmanager
def _file_lock(path: Path):
    """Best-effort exclusive flock on <path>.lock: serializes the
    read-modify-write across processes so concurrent tuner runs don't drop
    each other's entries.  Degrades to unlocked where flock is unavailable
    (the atomic rename still prevents torn files, just not lost updates)."""
    lf = None
    try:
        import fcntl

        lf = open(path.with_suffix(".lock"), "w")
        fcntl.flock(lf, fcntl.LOCK_EX)
    except (ImportError, OSError):
        if lf is not None:
            lf.close()
            lf = None
    try:
        yield
    finally:
        if lf is not None:
            try:
                lf.close()  # closing drops the flock
            except OSError:
                pass


def store(
    kernel: str,
    shape,
    dtype: str,
    backend: str,
    config: dict,
    source: str = "analytic",
    cost: Optional[dict] = None,
    directory: Optional[Path] = None,
) -> bool:
    """Locked read-modify-write of one entry (atomic rename).
    False if the FS said no."""
    path = _backend_path(backend, directory)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        with _file_lock(path):
            entries = load_all(backend, directory)
            entries[entry_key(kernel, shape, dtype)] = {
                "config": {k: int(v) for k, v in config.items()},
                "source": source,
                "cost": cost or {},
            }
            fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(
                        {"schema": SCHEMA_VERSION, "entries": entries}, f, indent=1, sort_keys=True
                    )
                os.replace(tmp, path)
            finally:
                # a failed write must not orphan the temp file (after a
                # successful replace the unlink is a no-op)
                with contextlib.suppress(OSError):
                    os.unlink(tmp)
        return True
    except OSError:
        return False
