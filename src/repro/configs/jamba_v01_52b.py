"""Jamba v0.1 52B [arXiv:2403.19887; hf].  32L d=4096 32H (GQA kv=8)
d_ff=14336 vocab=65536 — Mamba:attention 7:1 interleave (attention at
position 4 of each 8-layer period), 16-expert top-2 MoE on every other
layer (odd positions)."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    # 8-layer period: attn at index 4 (1:7), MoE at odd indices (every other)
    pattern = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        pattern.append(BlockSpec(mixer=mixer, ffn=ffn))
    return ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        pattern=tuple(pattern),
        n_experts=16,
        top_k=2,
        moe_d_ff=14336,
        ssm_d_state=16,
        ssm_d_conv=4,
        ssm_expand=2,
        ssm_unroll=8,  # §Perf: -53% memory term
        moe_group_size=4096,
        tie_embeddings=False,
        optimizer_moment_dtype="bfloat16",
        source="arXiv:2403.19887; hf",
    )
