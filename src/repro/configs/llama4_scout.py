"""Llama-4 Scout 17B-active/16E [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified].  48L d=5120 40H (GQA kv=8) d_ff=8192 vocab=202048 — 16-expert
top-1 MoE with an always-on shared expert; early-fusion frontend stubbed."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        pattern=(BlockSpec(mixer="attn", ffn="moe"),),
        n_experts=16,
        top_k=1,
        moe_d_ff=8192,
        shared_expert=True,
        moe_group_size=4096,
        rope_theta=5e5,
        tie_embeddings=False,
        optimizer_moment_dtype="bfloat16",
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    )
