"""Gemma2-2B [arXiv:2408.00118; hf].  26L d=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local(4096)+global alternating, attn softcap 50, final logit
softcap 30, sandwich (pre+post) norms, embedding scaled by sqrt(d)."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-2b",
        family="dense",
        n_layers=26,
        d_model=2304,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        pattern=(
            BlockSpec(mixer="attn", attn_type="local", ffn="dense"),
            BlockSpec(mixer="attn", attn_type="global", ffn="dense"),
        ),
        window_size=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_block_norm=True,
        scale_embed=True,
        activation="gelu",
        attn_scale=1.0 / 16.0,  # query_pre_attn_scalar = 256
        tie_embeddings=True,
        source="arXiv:2408.00118; hf",
    )
