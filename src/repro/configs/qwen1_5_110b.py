"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family; hf].  80L d=8192 64H (GQA kv=8)
d_ff=49152 vocab=152064 — QKV bias."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-110b",
        family="dense",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=49152,
        vocab_size=152064,
        pattern=(BlockSpec(mixer="attn", ffn="dense"),),
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=False,
        source="hf:Qwen/Qwen1.5-0.5B; hf",
    )
