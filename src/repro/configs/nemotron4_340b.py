"""Nemotron-4-340B [arXiv:2402.16819; unverified].  96L d=18432 96H (GQA
kv=8) d_ff=73728 vocab=256000 — squared-ReLU MLP (no gate)."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73728,
        vocab_size=256000,
        pattern=(BlockSpec(mixer="attn", ffn="dense"),),
        activation="squared_relu",
        rope_theta=10000.0,
        tie_embeddings=False,
        optimizer_moment_dtype="bfloat16",
        source="arXiv:2402.16819; unverified",
    )
