"""MusicGen-large [arXiv:2306.05284; hf].  48L d=2048 32H (MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens.  The EnCodec frontend is a
STUB per the assignment: inputs are (B, S, n_q=4) codebook token ids; the
backbone sums per-codebook embeddings and predicts 4 parallel heads."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        pattern=(BlockSpec(mixer="attn", ffn="dense"),),
        activation="gelu",
        frontend="audio_codes",
        n_codebooks=4,
        tie_embeddings=False,
        source="arXiv:2306.05284; hf",
    )
