"""RWKV-6 (Finch) 3B [arXiv:2404.05892; hf].  32L d=2560 attn-free
d_ff=8960 vocab=65536 — data-dependent decay linear recurrence; each layer
is a time-mix (mixer) + channel-mix (ffn) pair."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=1,  # attention-free; rwkv heads come from rwkv_head_dim
        n_kv_heads=1,
        d_ff=8960,
        vocab_size=65536,
        pattern=(BlockSpec(mixer="rwkv", ffn="rwkv_cmix"),),
        rwkv_head_dim=64,
        rwkv_chunk=64,  # chunk-parallel recurrence (EXPERIMENTS §Perf: 203x memory term)
        tie_embeddings=False,
        source="arXiv:2404.05892; hf",
    )
