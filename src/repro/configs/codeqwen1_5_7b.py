"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B; hf].  32L d=4096 32H (GQA kv=32 =
MHA) d_ff=13440 vocab=92416 — qwen1.5 arch, QKV bias."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        pattern=(BlockSpec(mixer="attn", ffn="dense"),),
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=False,
        source="hf:Qwen/CodeQwen1.5-7B; hf",
    )
