"""The paper's own SSL setting: Siamese backbone + 3-layer MLP projector,
Barlow Twins / VICReg / proposed losses.  The backbone here is a compact
conv-free patch MLP (the paper's ResNets are orthogonal to its
contribution); projector widths d in {2048 ... 16384} as in Fig. 2."""

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class SSLConfig:
    input_dim: int = 3 * 32 * 32
    backbone_widths: Tuple[int, ...] = (512, 512)
    projector_widths: Tuple[int, ...] = (2048, 2048, 2048)
    batch_size: int = 256


def config() -> SSLConfig:
    return SSLConfig()
