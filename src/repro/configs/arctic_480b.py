"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base; hf].  35L
d=7168 56H (GQA kv=8) vocab=32000 — 128-expert top-2 MoE (expert d_ff=4864)
with a DENSE residual MLP in parallel on every layer."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        pattern=(BlockSpec(mixer="attn", ffn="moe"),),
        n_experts=128,
        top_k=2,
        moe_d_ff=4864,
        dense_residual=True,
        moe_group_size=4096,  # §Perf: dispatch O(T*G), compute term -54%
        tie_embeddings=False,
        optimizer_moment_dtype="bfloat16",
        source="hf:Snowflake/snowflake-arctic-base; hf",
    )
