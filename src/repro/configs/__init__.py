"""Architecture registry: one module per assigned arch + the paper's own SSL
config.  ``get_config(name)`` / ``list_archs()`` are the public API;
``--arch <id>`` in the launchers resolves through here."""

from __future__ import annotations

import importlib
from typing import List

_ARCHS = {
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "nemotron-4-340b": "repro.configs.nemotron4_340b",
    "codeqwen1.5-7b": "repro.configs.codeqwen1_5_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "musicgen-large": "repro.configs.musicgen_large",
    "ssl-paper": "repro.configs.ssl_paper",
}


def list_archs() -> List[str]:
    return [k for k in _ARCHS if k != "ssl-paper"]


def get_config(name: str):
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(_ARCHS[name])
    return mod.config()
