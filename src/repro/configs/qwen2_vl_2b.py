"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf].  28L d=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936 — M-RoPE, dynamic resolution.  Vision frontend is a
STUB per the assignment: input_specs() provides precomputed patch embeddings
plus the (3, B, S) M-RoPE position streams."""

from repro.models.common import ArchConfig, BlockSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        pattern=(BlockSpec(mixer="attn", ffn="dense"),),
        mrope=True,
        mrope_sections=(16, 24, 24),
        qkv_bias=True,
        rope_theta=1e6,
        frontend="vision_stub",
        tie_embeddings=True,
        source="arXiv:2409.12191; hf",
    )
