"""Stdlib markdown link + anchor checker for docs/ and README.md.

Walks every markdown page, extracts inline links/images, and fails when a
relative link points at a file that does not exist or an ``#anchor`` that no
heading in the target page generates.  External (``http(s)://``, ``mailto:``)
targets and relative targets resolving outside the repo (the CI badge's
``../../actions/...`` URL) are skipped — this is a repo-consistency check,
not a crawler.

Run from the repo root: ``python tools/check_docs.py``
"""

from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)
_ANCHOR_DROP = re.compile(r"[^\w\- ]")


def github_anchor(heading: str) -> str:
    """The anchor GitHub generates for a heading: lowercase, punctuation
    stripped (underscores and hyphens survive), spaces to hyphens."""
    text = re.sub(r"`([^`]*)`", r"\1", heading.strip())
    text = _ANCHOR_DROP.sub("", text.lower())
    return text.replace(" ", "-")


def anchors_of(md_text: str) -> set:
    """All heading anchors a markdown page exposes (code fences excluded)."""
    return {github_anchor(h) for h in HEADING.findall(CODE_FENCE.sub("", md_text))}


def check(root: pathlib.Path) -> int:
    """Check every docs/*.md page plus README.md; return the error count."""
    pages = sorted(root.glob("docs/*.md")) + [root / "README.md"]
    texts = {p: p.read_text() for p in pages if p.exists()}
    errors = 0
    for page, text in texts.items():
        for target in LINK.findall(CODE_FENCE.sub("", text)):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = (page.parent / path_part).resolve() if path_part else page
            if path_part and root.resolve() not in dest.parents and dest != root.resolve():
                continue  # outside the repo (e.g. the CI badge link)
            if not dest.exists():
                print(f"{page.relative_to(root)}: broken link -> {target}")
                errors += 1
                continue
            if anchor and dest.suffix == ".md":
                dest_text = texts.get(dest) or dest.read_text()
                if github_anchor(anchor) not in anchors_of(dest_text):
                    print(f"{page.relative_to(root)}: missing anchor -> {target}")
                    errors += 1
    print(f"[docs] checked {len(texts)} pages: {errors} broken link(s)")
    return errors


if __name__ == "__main__":
    sys.exit(1 if check(pathlib.Path(__file__).resolve().parent.parent) else 0)
