"""Distributed decorrelation engine (DESIGN.md §4): per-mode SSL step time +
collective bytes from compiled HLO, on an 8-virtual-device subprocess.

Validates the beyond-paper claim: ``global`` mode upgrades every statistic in
the loss (moments, diagonal, frequency accumulator) to the exact global batch
for O(d) psum traffic — versus the O(n d) all-gather a naive global
implementation needs.  Emits ``BENCH_distributed.json``; CI gates that
``global`` mode's extra loss traffic stays O(d) (a handful of length-d
accumulator psums, NOT an n x d gather).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import fmt_row

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import time

import jax
import jax.numpy as jnp
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import distributed as dist
from repro.core import regularizers as regs
from repro.core.losses import DecorrConfig, ssl_loss
from repro.launch.hlo_cost import analyze_hlo
from repro.train.ssl import (SSLModelConfig, init_ssl_params,
                             make_sharded_ssl_train_step, shard_ssl_batch)
from repro.optim import adamw, warmup_cosine

out = {}

# ---- regularizer-level collective traffic (n, d) = (256, 2048) ----------
n, d = 256, 2048
out["reg"] = {"n": n, "d": d}
mesh = jax.make_mesh((8,), ("data",))
z1 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
z2 = jax.random.normal(jax.random.PRNGKey(1), (n, d))

local = shard_map(lambda a, b: regs.r_sum(a, b, q=2, scale=float(a.shape[0]))[None],
                  mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P("data"))
out["reg"]["local_coll_bytes"] = analyze_hlo(
    jax.jit(local).lower(z1, z2).compile().as_text()).total_collective_bytes

glob = shard_map(lambda a, b: dist.r_sum_global(a, b, axis_name="data", q=2, scale=a.shape[0])[None],
                 mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())
out["reg"]["global_coll_bytes"] = analyze_hlo(
    jax.jit(glob).lower(z1, z2).compile().as_text()).total_collective_bytes
out["reg"]["global_value"] = float(glob(z1, z2)[0])
out["reg"]["exact_value"] = float(regs.r_sum(z1, z2, q=2, scale=n))

naive = shard_map(lambda a, b: regs.r_sum(
    jax.lax.all_gather(a, "data", tiled=True), jax.lax.all_gather(b, "data", tiled=True),
    q=2, scale=float(n))[None], mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P("data"))
out["reg"]["naive_global_coll_bytes"] = analyze_hlo(
    jax.jit(naive).lower(z1, z2).compile().as_text()).total_collective_bytes

mesh2 = jax.make_mesh((2, 4), ("data", "model"))
tp = shard_map(lambda a, b: dist.r_sum_tp(a, b, model_axis="model", batch_axis="data",
                                          q=2, scale=a.shape[0])[None],
               mesh=mesh2, in_specs=(P("data", "model"), P("data", "model")), out_specs=P())
out["reg"]["tp_coll_bytes"] = analyze_hlo(
    jax.jit(tp).lower(z1, z2).compile().as_text()).total_collective_bytes
out["reg"]["tp_value"] = float(tp(z1, z2)[0])

# ---- full SSL train step per engine mode --------------------------------
n_ssl, d_ssl = 128, 512
out["ssl"] = {"n": n_ssl, "d": d_ssl}
model = SSLModelConfig(input_dim=64, backbone_widths=(128,), projector_widths=(d_ssl, d_ssl))
params = init_ssl_params(jax.random.PRNGKey(0), model)
batch = {"view1": jax.random.normal(jax.random.PRNGKey(2), (n_ssl, 64)),
         "view2": jax.random.normal(jax.random.PRNGKey(3), (n_ssl, 64))}
rng = jax.random.PRNGKey(4)

for mode in ("local", "global", "tp"):
    m = jax.make_mesh((8,), ("data",)) if mode != "tp" else jax.make_mesh((2, 4), ("data", "model"))
    cfg = DecorrConfig(style="bt", reg="sum", q=2, block_size=128, distributed=mode)
    step, lag = make_sharded_ssl_train_step(model, cfg, adamw(), warmup_cosine(1e-3, 2, 10), m)
    sb = shard_ssl_batch(batch, m)

    # loss+grad collective bytes (the decorr engine's own traffic + grad reduce)
    lagj = jax.jit(lag)
    a = analyze_hlo(lagj.lower(params, sb, rng).compile().as_text())
    # forward-only loss traffic: grads dominate the step, so gate on this
    fwd = jax.jit(lambda p, b, r: lag(p, b, r)[0])
    af = analyze_hlo(fwd.lower(params, sb, rng).compile().as_text())

    loss, _, _ = lagj(params, sb, rng)
    t0 = time.time()
    for _ in range(3):
        loss, _, grads = lagj(params, sb, rng)
    jax.block_until_ready(grads)
    out[mode] = {
        "loss_fwd_coll_bytes": af.total_collective_bytes,
        "loss_grad_coll_bytes": a.total_collective_bytes,
        "step_us": (time.time() - t0) / 3 * 1e6,
        "loss": float(loss),
    }

# ---- O(d) gate: global's extra FORWARD loss traffic vs an n x d gather ---
extra = out["global"]["loss_fwd_coll_bytes"] - out["local"]["loss_fwd_coll_bytes"]
budget = 128 * d_ssl + 16384  # a handful of length-d psums (ring-counted)
gather = 2 * n_ssl * d_ssl * 4  # what all-gathering both views would move
out["gate"] = {"extra_bytes": extra, "budget_bytes": budget,
               "allgather_bytes": gather,
               "ok": bool(extra <= budget and extra < gather)}
print(json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    code = textwrap.dedent(_BODY)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=560
    )
    if proc.returncode != 0:
        return [fmt_row("distributed/ERROR", 0.0, proc.stderr.strip()[-200:].replace(",", ";"))]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    with open(os.path.join(os.getcwd(), "BENCH_distributed.json"), "w") as f:
        json.dump(res, f, indent=2, sort_keys=True)
    reg = res["reg"]
    rows = [
        fmt_row("distributed/local", 0.0, f"loss_collective_bytes={reg['local_coll_bytes']:.3g}"),
        fmt_row(
            "distributed/global", 0.0,
            f"loss_collective_bytes={reg['global_coll_bytes']:.3g};"
            f"value_err={abs(reg['global_value']-reg['exact_value']):.2e};"
            f"vs_naive_allgather={reg['naive_global_coll_bytes']/max(reg['global_coll_bytes'],1):.0f}x_less",
        ),
        fmt_row(
            "distributed/tp", 0.0,
            f"loss_collective_bytes={reg['tp_coll_bytes']:.3g};"
            f"value_err={abs(reg['tp_value']-reg['exact_value']):.2e}",
        ),
    ]
    for mode in ("local", "global", "tp"):
        m = res[mode]
        rows.append(fmt_row(
            f"distributed/ssl_step_{mode}", m["step_us"],
            f"fwd_coll_bytes={m['loss_fwd_coll_bytes']:.3g};"
            f"grad_coll_bytes={m['loss_grad_coll_bytes']:.3g}",
        ))
    g = res["gate"]
    rows.append(fmt_row(
        "distributed/gate_global_O(d)", 0.0,
        f"extra_bytes={g['extra_bytes']:.3g};budget={g['budget_bytes']:.3g};"
        f"allgather={g['allgather_bytes']:.3g};ok={g['ok']}",
    ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
