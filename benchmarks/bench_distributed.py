"""Distributed decorrelation modes (DESIGN.md §4): collective bytes and
numerical agreement of local / global / tp on an 8-device subprocess.

Validates the beyond-paper claim: `global` mode upgrades the statistic to
the exact global batch for one psum of ~(d/2+1) complex numbers — versus
the O(n d) all-gather a naive global implementation would need.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import fmt_row

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BODY = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import distributed as dist
from repro.core import regularizers as regs
from repro.launch.hlo_cost import analyze_hlo

n, d = 256, 2048
mesh = jax.make_mesh((8,), ("data",))
z1 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
z2 = jax.random.normal(jax.random.PRNGKey(1), (n, d))
out = {}

# local (paper DDP): no collectives in the loss
local = shard_map(lambda a, b: regs.r_sum(a, b, q=2, scale=float(a.shape[0]))[None],
                  mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P("data"))
c = jax.jit(local).lower(z1, z2).compile()
a = analyze_hlo(c.as_text())
out["local_coll_bytes"] = a.total_collective_bytes

# global: one psum of the frequency accumulator
glob = shard_map(lambda a, b: dist.r_sum_global(a, b, axis_name="data", q=2, scale=a.shape[0])[None],
                 mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P())
c = jax.jit(glob).lower(z1, z2).compile()
a = analyze_hlo(c.as_text())
out["global_coll_bytes"] = a.total_collective_bytes
out["global_value"] = float(glob(z1, z2)[0])
out["exact_value"] = float(regs.r_sum(z1, z2, q=2, scale=n))

# naive global: all-gather the embeddings then compute
naive = shard_map(lambda a, b: regs.r_sum(
    jax.lax.all_gather(a, "data", tiled=True), jax.lax.all_gather(b, "data", tiled=True),
    q=2, scale=float(n))[None], mesh=mesh, in_specs=(P("data"), P("data")), out_specs=P("data"))
c = jax.jit(naive).lower(z1, z2).compile()
a = analyze_hlo(c.as_text())
out["naive_global_coll_bytes"] = a.total_collective_bytes

# tp: feature-sharded with batch<->feature all_to_all
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
tp = shard_map(lambda a, b: dist.r_sum_tp(a, b, model_axis="model", batch_axis="data",
                                          q=2, scale=a.shape[0])[None],
               mesh=mesh2, in_specs=(P("data", "model"), P("data", "model")), out_specs=P())
c = jax.jit(tp).lower(z1, z2).compile()
a = analyze_hlo(c.as_text())
out["tp_coll_bytes"] = a.total_collective_bytes
out["tp_value"] = float(tp(z1, z2)[0])
print(json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    code = textwrap.dedent(_BODY)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=420
    )
    if proc.returncode != 0:
        return [fmt_row("distributed/ERROR", 0.0, proc.stderr.strip()[-200:].replace(",", ";"))]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = [
        fmt_row("distributed/local", 0.0, f"loss_collective_bytes={res['local_coll_bytes']:.3g}"),
        fmt_row(
            "distributed/global", 0.0,
            f"loss_collective_bytes={res['global_coll_bytes']:.3g};"
            f"value_err={abs(res['global_value']-res['exact_value']):.2e};"
            f"vs_naive_allgather={res['naive_global_coll_bytes']/max(res['global_coll_bytes'],1):.0f}x_less",
        ),
        fmt_row(
            "distributed/tp", 0.0,
            f"loss_collective_bytes={res['tp_coll_bytes']:.3g};"
            f"value_err={abs(res['tp_value']-res['exact_value']):.2e}",
        ),
    ]
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
