"""Bench-regression gate: current BENCH_*.json vs committed baselines.

CI runs the bench suites, then::

    PYTHONPATH=src python -m benchmarks.compare serve tune

Two kinds of checks per suite:

  * **hard gates** — absolute invariants that must hold on any machine
    (micro-batching beats naive, continuous batching beats whole-request
    with zero token mismatches, probes agree with the training-path oracle,
    tuned kernel costs <= default);
  * **baseline regression** — RATIO metrics (speedups, tuned/default cost
    ratios) compared against ``benchmarks/baselines/BENCH_*.json``.  Ratios
    are machine-portable where absolute throughput is not; a ratio more than
    ``REL_TOL`` (20%) worse than the committed baseline fails the gate.

``--write-baseline`` snapshots the current reports into the baselines dir
(run locally, commit the result) after an intentional perf change.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

REL_TOL = 0.20
BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "baselines")


def _lookup(report: dict, path: str):
    cur = report
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _tune_ratio_metrics(report: dict) -> Dict[str, float]:
    out = {}
    for k in report.get("kernels", []):
        name = f"{k['kernel']}{tuple(k['shape'])}"
        out[f"{name}.flops_ratio"] = float(k["flops_ratio"])
        out[f"{name}.bytes_ratio"] = float(k["bytes_ratio"])
    return out


# (path, predicate, description) hard gates per suite
HARD_GATES = {
    "serve": [
        ("gate.microbatch_beats_naive", lambda v: bool(v), "micro-batched throughput >= naive"),
        ("probe.oracle_rel_err", lambda v: v < 1e-3, "embedding probe matches training oracle"),
        ("lm.gate.continuous_beats_whole_request", lambda v: bool(v),
         "continuous-batching tok/s >= whole-request generate"),
        ("lm.gate.token_mismatches", lambda v: v == 0,
         "slot interleaving changes no request's tokens"),
        ("lm.gate.probe_oracle_rel_err", lambda v: v < 1e-3,
         "in-flight probe matches training oracle under interleaving"),
        ("paged.gate.token_mismatches", lambda v: v == 0,
         "paged KV cache changes no request's greedy tokens"),
        ("paged.gate.paged_peak_lt_dense", lambda v: bool(v),
         "paged peak cache bytes < dense pool at the skewed length mix"),
        ("prefix.gate.token_mismatches", lambda v: v == 0,
         "prefix sharing changes no request's greedy tokens"),
        ("prefix.gate.warm_ttft_lt_unshared", lambda v: bool(v),
         "warm-prefix TTFT strictly below the unshared paged run"),
        ("prefix.gate.peak_pages_lt_unshared", lambda v: bool(v),
         "prefix sharing's peak pool pages strictly below unshared"),
        ("prefix.gate.prefix_hit_rate", lambda v: v > 0,
         "the radix cache actually served hits on the fan-out workload"),
        ("prefix.gate.probe_oracle_rel_err", lambda v: v < 1e-3,
         "in-flight probe matches training oracle under page sharing"),
        ("spec.gate.token_mismatches", lambda v: v == 0,
         "speculative decode changes no request's greedy tokens"),
        ("spec.gate.spec_beats_plain", lambda v: bool(v),
         "speculative tok/s >= plain paged decode on the decode-heavy mix"),
        ("spec.gate.accepted_tokens_per_step", lambda v: v > 1,
         "each verify step emits more than one token on average"),
        ("obs.gate.overhead_ok", lambda v: bool(v),
         "always-on telemetry keeps >= 95% of telemetry-off tok/s"),
        ("perf.gate.has_required", lambda v: bool(v),
         "attribution covers embed buckets, prefill buckets, decode tick, "
         "chunked prefill and the probe update"),
        ("perf.gate.nonzero_samples", lambda v: bool(v),
         "every attributed executable has nonzero wall-time samples"),
        ("perf.gate.utilization_ok", lambda v: bool(v),
         "every attributed executable's roofline utilization is in (0, 1]"),
        ("fabric.gate.token_mismatches", lambda v: v == 0,
         "replica routing changes no request's greedy tokens"),
        ("fabric.gate.requeue_token_mismatches", lambda v: v == 0,
         "failover requeue re-derives every killed replica's tokens bit-exactly"),
        ("fabric.gate.requeued", lambda v: v > 0,
         "the kill-one-replica leg actually stranded and requeued requests"),
        ("fabric.gate.scaling_ok", lambda v: bool(v),
         "N-replica aggregate tok/s meets the hardware-aware scaling target"),
        ("fabric.gate.tp_rel_err", lambda v: v < 1e-5,
         "feature-sharded tp forward matches the single-device oracle"),
        ("fabric.gate.embed_max_abs_err", lambda v: v < 1e-5,
         "embedding results are route-independent across replicas"),
    ],
    "tune": [],  # per-kernel gates generated below
}

# suite -> (bench file, {metric name: (direction, dotted path)})
#   direction: +1 higher is better (speedups), -1 lower is better (costs)
RATIO_METRICS = {
    "serve": {
        "microbatch_speedup": (+1, "gate.speedup"),
        "continuous_speedup": (+1, "lm.gate.speedup"),
        "slot_occupancy": (+1, "lm.service_metrics.slots_occupancy"),
        # peak_cache_bytes_ratio is deterministic (same workload, same
        # allocator) — gate it; tok_per_s_ratio is reported in the JSON but
        # too load-sensitive on CPU CI to gate against a snapshot baseline
        "paged_peak_bytes_ratio": (-1, "paged.gate.peak_cache_bytes_ratio"),
        # deterministic for the same reason: page arithmetic, not wall clock
        "prefix_peak_pages_ratio": (-1, "prefix.gate.peak_pages_ratio"),
    },
    "tune": {},  # per-kernel ratios generated from the report
}

FILES = {"serve": "BENCH_serve.json", "tune": "BENCH_tune.json"}


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_suite(
    suite: str, current: dict, baseline: dict | None
) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes)."""
    failures, notes = [], []

    # hard gates
    if suite == "tune":
        for k in current.get("kernels", []):
            name = f"{k['kernel']}{tuple(k['shape'])}"
            for key in ("flops_ratio", "bytes_ratio"):
                if float(k[key]) > 1.0:
                    failures.append(f"[{suite}] tuned worse than default: {name}.{key}={k[key]:.3f}")
    for path, pred, desc in HARD_GATES.get(suite, []):
        v = _lookup(current, path)
        if v is None:
            failures.append(f"[{suite}] missing gate metric {path} ({desc})")
        elif not pred(v):
            failures.append(f"[{suite}] HARD gate failed: {desc} ({path}={v})")
        else:
            notes.append(f"[{suite}] ok: {desc} ({path}={v})")

    # baseline ratio regression
    if baseline is None:
        notes.append(f"[{suite}] no baseline committed — regression check skipped")
        return failures, notes
    if suite == "tune":
        cur_m = _tune_ratio_metrics(current)
        base_m = _tune_ratio_metrics(baseline)
        pairs = {name: (-1, cur_m[name], base_m.get(name)) for name in cur_m}
    else:
        pairs = {
            name: (direction, _lookup(current, path), _lookup(baseline, path))
            for name, (direction, path) in RATIO_METRICS[suite].items()
        }
    for name, (direction, cur, base) in pairs.items():
        if cur is None or base is None or base == 0:
            notes.append(f"[{suite}] {name}: not comparable (cur={cur}, base={base})")
            continue
        if direction > 0:
            ok = cur >= base * (1.0 - REL_TOL)
        else:
            ok = cur <= base * (1.0 + REL_TOL)
        line = f"{name}: current={cur:.3f} baseline={base:.3f} (tol {REL_TOL:.0%})"
        if ok:
            notes.append(f"[{suite}] ok: {line}")
        else:
            failures.append(f"[{suite}] REGRESSION >{REL_TOL:.0%}: {line}")
    return failures, notes


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="benchmarks.compare", description=__doc__)
    p.add_argument("suites", nargs="*", default=None,
                   help="which suites to gate (default: all with a bench file present)")
    p.add_argument("--current-dir", default=".",
                   help="where the freshly produced BENCH_*.json live")
    p.add_argument("--baseline-dir", default=BASELINE_DIR)
    p.add_argument("--write-baseline", action="store_true",
                   help="snapshot current reports as the new committed baselines")
    args = p.parse_args(argv)

    suites = args.suites or [s for s in FILES
                             if os.path.exists(os.path.join(args.current_dir, FILES[s]))]
    if not suites:
        print("benchmarks.compare: no BENCH_*.json found; run `python -m benchmarks.run` first")
        return 2

    all_failures: List[str] = []
    for suite in suites:
        cur_path = os.path.join(args.current_dir, FILES[suite])
        if not os.path.exists(cur_path):
            all_failures.append(f"[{suite}] missing {cur_path}")
            continue
        current = _load(cur_path)
        if args.write_baseline:
            os.makedirs(args.baseline_dir, exist_ok=True)
            dst = os.path.join(args.baseline_dir, FILES[suite])
            with open(dst, "w") as f:
                json.dump(current, f, indent=2, sort_keys=True)
            print(f"[{suite}] baseline written: {dst}")
            continue
        base_path = os.path.join(args.baseline_dir, FILES[suite])
        baseline = _load(base_path) if os.path.exists(base_path) else None
        failures, notes = check_suite(suite, current, baseline)
        for n in notes:
            print(n)
        for fail in failures:
            print(fail, file=sys.stderr)
        all_failures.extend(failures)

    if all_failures:
        print(f"\nbench gate FAILED ({len(all_failures)} violations)", file=sys.stderr)
        return 1
    if not args.write_baseline:
        print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
