"""Fig. 3 analogue: impact of the block size b at fixed d = 2048.

The paper's finding: b >= ~16 shows no significant time/memory increase over
ungrouped; moderate b (128) is the accuracy sweet spot.  Here we chart the
compiled FLOPs/bytes + CPU wall time across b.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import compiled_costs, fmt_row, sds, time_fn
from repro.core import regularizers as regs

N, D = 256, 2048
BS = (2, 8, 32, 128, 512, 2048)


def run():
    rows = []
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    z1 = jax.random.normal(k1, (N, D))
    z2 = jax.random.normal(k2, (N, D))
    for b in BS:
        fn = lambda a, c: regs.r_sum_auto(a, c, q=2, block_size=b, scale=float(N))
        vg = lambda a, c: jax.value_and_grad(fn, argnums=(0, 1))(a, c)
        costs = compiled_costs(vg, sds((N, D)), sds((N, D)))
        us = time_fn(jax.jit(vg), z1, z2, repeats=3)
        rows.append(
            fmt_row(
                f"blocksize/b{b}",
                us,
                f"flops={costs['flops']:.3e};bytes={costs['hbm_bytes']:.3e}",
            )
        )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
