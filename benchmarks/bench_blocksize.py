"""Fig. 3 analogue: impact of the block size b at fixed d = 2048.

The paper's finding: b >= ~16 shows no significant time/memory increase over
ungrouped; moderate b (128) is the accuracy sweet spot.  Here we chart the
compiled FLOPs/bytes + CPU wall time across b.

The b values are no longer a hand-picked list: they come from the tuner's
candidate enumeration (``repro.tune.grouped_block_size_candidates``),
subsampled to keep the suite's wall time bounded.
"""

from __future__ import annotations

import jax

from benchmarks.common import compiled_costs, fmt_row, sds, time_fn
from repro import tune
from repro.core import regularizers as regs

N, D = 256, 2048
MAX_POINTS = 6


def block_sizes(d: int = D, max_points: int = MAX_POINTS) -> list[int]:
    """The tuner's legal b candidates for width d, evenly subsampled."""
    if max_points < 1:
        raise ValueError(f"max_points must be >= 1, got {max_points}")
    bs = tune.grouped_block_size_candidates(d)
    if len(bs) <= max_points:
        return bs
    if max_points == 1:
        return [bs[-1]]
    stride = (len(bs) - 1) / (max_points - 1)
    return [bs[round(i * stride)] for i in range(max_points)]


def run():
    rows = []
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    z1 = jax.random.normal(k1, (N, D))
    z2 = jax.random.normal(k2, (N, D))
    for b in block_sizes():
        fn = lambda a, c: regs.r_sum_auto(a, c, q=2, block_size=b, scale=float(N))
        vg = lambda a, c: jax.value_and_grad(fn, argnums=(0, 1))(a, c)
        costs = compiled_costs(vg, sds((N, D)), sds((N, D)))
        us = time_fn(jax.jit(vg), z1, z2, repeats=3)
        rows.append(
            fmt_row(
                f"blocksize/b{b}",
                us,
                f"flops={costs['flops']:.3e};bytes={costs['hbm_bytes']:.3e}",
            )
        )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
