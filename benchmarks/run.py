"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run complexity # one suite
"""

from __future__ import annotations

import sys
import time

SUITES = {
    "complexity": "benchmarks.bench_complexity",       # Fig. 2 / Table 7
    "blocksize": "benchmarks.bench_blocksize",         # Fig. 3
    "permutation": "benchmarks.bench_permutation",     # Tables 5 & 6
    "q": "benchmarks.bench_q",                         # Table 11
    "training_time": "benchmarks.bench_training_time", # Table 4 / 12 / 13
    "equivalence": "benchmarks.bench_loss_equivalence",# kernel agreement
    "distributed": "benchmarks.bench_distributed",     # DESIGN §4 modes
    "roofline": "benchmarks.roofline",                 # §Roofline (from dryrun)
    "tune": "benchmarks.bench_tune",                   # default-vs-tuned -> BENCH_tune.json
    "serve": "benchmarks.bench_serve",                 # serving policies -> BENCH_serve.json
}


def main() -> None:
    import importlib

    wanted = sys.argv[1:] or list(SUITES)
    print("name,us_per_call,derived")
    for key in wanted:
        mod = importlib.import_module(SUITES[key])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the harness going
            rows = [f"{key}/ERROR,0,{type(e).__name__}: {e}"]
        for row in rows:
            print(row, flush=True)
        print(f"# suite {key} took {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
