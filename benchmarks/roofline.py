"""§Roofline table generator: reads dryrun_results/*.json and emits the
per-(arch x shape x mesh) roofline analysis (markdown + CSV rows)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import fmt_row

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "dryrun_results")

V5E_HBM = 16e9


def _mesh_name(rec: Dict) -> str:
    m = rec.get("mesh")
    if isinstance(m, str):
        return m
    return "pod2x16x16" if "pod" in m else "pod16x16"


def load_records(results_dir: str = RESULTS_DIR) -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def one_sentence(rec: Dict) -> str:
    """What would move the dominant term down (per-cell guidance)."""
    dom = rec["roofline"]["dominant"]
    arch, shape = rec["arch"], rec["shape"]
    if dom == "memory":
        if arch in ("rwkv6-3b",) or (arch == "jamba-v0.1-52b" and shape != "decode_32k"):
            return "chunk the recurrence (block-parallel scan) to amortize state traffic over many tokens per HBM round-trip"
        if shape == "train_4k":
            return "fewer microbatches / fused attention (no materialized scores) to cut re-read of weights and score tensors"
        return "fuse attention (chunked online softmax) and keep KV in bf16 to cut score-tensor traffic"
    if dom == "collective":
        return "re-shard to cut all-gathers (2D weight sharding aligned with use), overlap collectives with compute, compress gradients"
    return "raise arithmetic intensity: larger per-device microbatch or cheaper dispatch (chunked MoE routing)"


def markdown_table(recs: List[Dict], with_guidance: bool = True) -> str:
    head = "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant | MODEL_FLOPS/dev | useful ratio | fits 16GB |"
    sep = "|---|---|---|---|---|---|---|---|---|---|"
    if with_guidance:
        head += " what moves the dominant term down |"
        sep += "---|"
    lines = [head, sep]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], _mesh_name(r))):
        if r["status"] == "skipped":
            row = f"| {r['arch']} | {r['shape']} | {_mesh_name(r)} | — | — | — | skipped | — | — | {r['reason'][:60]} |"
            lines.append(row + (" |" if with_guidance else ""))
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {_mesh_name(r)} | ERROR | | | | | | |" + (" |" if with_guidance else ""))
            continue
        rl = r["roofline"]
        fits = (r["memory"]["temp_bytes"] + r["memory"]["argument_bytes"]) < V5E_HBM
        row = (
            f"| {r['arch']} | {r['shape']} | {_mesh_name(r)} "
            f"| {rl['compute_s']:.3g} | {rl['memory_s']:.3g} | {rl['collective_s']:.3g} "
            f"| {rl['dominant']} | {r['model_flops_per_device']:.3g} "
            f"| {r['useful_flops_ratio']:.3f} | {'yes' if fits else 'NO'} |"
        )
        if with_guidance:
            row += f" {one_sentence(r)} |"
        lines.append(row)
    return "\n".join(lines)


def run():
    recs = load_records()
    rows = []
    for r in recs:
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        rows.append(
            fmt_row(
                f"roofline/{r['arch']}/{r['shape']}/{_mesh_name(r)}",
                rl["bound_s"] * 1e6,
                f"dom={rl['dominant']};compute_s={rl['compute_s']:.3g};memory_s={rl['memory_s']:.3g};"
                f"collective_s={rl['collective_s']:.3g};useful={r['useful_flops_ratio']:.3f}",
            )
        )
    if not rows:
        rows.append(fmt_row("roofline/NO_RESULTS", 0.0, f"run dryrun first (dir={RESULTS_DIR})"))
    return rows


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs))
