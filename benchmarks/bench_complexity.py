"""Fig. 2 / Table 7 analogue: loss-node cost vs projector width d.

Measures, for n = 256 and d in a sweep:
  * compiled FLOPs + HBM bytes (trip-exact, single device) of the
    regularizer value-and-grad for:
      - R_off naive          (materialize C: the paper's baseline)
      - R_off Gram           (beyond-paper O(n^2 d) baseline strengthening)
      - R_sum FFT            (paper, q=2 Parseval path)
      - R_sum^(128) grouped  (paper, b=128)
  * wall-clock on this CPU for the sizes that are feasible.

The paper's claim: R_sum is O(nd log d) vs O(nd^2) — ratios grow with d.
"""

from __future__ import annotations

import jax

from benchmarks.common import compiled_costs, fmt_row, sds, time_fn
from repro.core import regularizers as regs
from repro.kernels.xcorr_offdiag.ops import r_off_gram

N = 256
DS_COST = (1024, 2048, 4096, 8192, 16384)
DS_WALL = (1024, 2048, 4096, 8192)


def _variants(n):
    return {
        "r_off_naive": lambda a, b: regs.r_off(regs.cross_correlation_matrix(a, b, scale=n)),
        "r_off_gram": lambda a, b: r_off_gram(a, b, scale=float(n)),
        "r_sum_fft": lambda a, b: regs.r_sum(a, b, q=2, scale=float(n)),
        "r_sum_b128": lambda a, b: regs.r_sum_grouped(a, b, 128, q=2, scale=float(n)),
    }


def run():
    rows = []
    for d in DS_COST:
        base_flops = None
        for name, fn in _variants(N).items():
            vg = lambda a, b: jax.value_and_grad(fn, argnums=(0, 1))(a, b)
            costs = compiled_costs(vg, sds((N, d)), sds((N, d)))
            if name == "r_off_naive":
                base_flops = costs["flops"]
            ratio = base_flops / max(costs["flops"], 1)
            rows.append(
                fmt_row(
                    f"complexity/{name}/d{d}",
                    0.0,
                    f"flops={costs['flops']:.3e};bytes={costs['hbm_bytes']:.3e};speedup_vs_naive={ratio:.1f}x",
                )
            )
    for d in DS_WALL:
        base_us = None
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        z1 = jax.random.normal(k1, (N, d))
        z2 = jax.random.normal(k2, (N, d))
        for name, fn in _variants(N).items():
            vg = jax.jit(lambda a, b: jax.value_and_grad(fn, argnums=(0, 1))(a, b))
            us = time_fn(vg, z1, z2, repeats=3)
            if name == "r_off_naive":
                base_us = us
            rows.append(
                fmt_row(f"complexity_wall/{name}/d{d}", us, f"speedup_vs_naive={base_us/us:.2f}x")
            )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
