"""Table 6 companion: numerical agreement of every computation path for the
same regularizer value — pure-jnp FFT, matrix oracle, Pallas grouped kernel,
Pallas four-step kernel, Gram baseline — plus kernel timings."""

from __future__ import annotations

import jax

from benchmarks.common import fmt_row, time_fn
from repro.core import regularizers as regs
from repro.kernels.grouped_sumvec import ops as gops, ref as gref
from repro.kernels.sumvec_fft import ops as fops
from repro.kernels.xcorr_offdiag import ops as xops, ref as xref

N, D, B = 64, 512, 64


def run():
    rows = []
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    z1 = jax.random.normal(k1, (N, D))
    z2 = jax.random.normal(k2, (N, D))

    want_g = float(gref.r_sum_grouped_ref(z1, z2, B, q=2, scale=N))
    got_jnp = float(regs.r_sum_grouped(z1, z2, B, q=2, scale=N))
    got_krn = float(gops.r_sum_kernel(z1, z2, block_size=B, q=2, scale=N))
    rows.append(fmt_row("equiv/grouped", 0.0,
                        f"oracle={want_g:.4f};jnp_err={abs(got_jnp-want_g):.2e};kernel_err={abs(got_krn-want_g):.2e}"))

    want_u = float(regs.r_sum(z1, z2, q=2, scale=N))
    got_4s = float(fops.r_sum_fourstep(z1, z2, q=2, scale=N))
    rows.append(fmt_row("equiv/fourstep", 0.0, f"jnp={want_u:.4f};kernel_err={abs(got_4s-want_u):.2e}"))

    want_o = float(xref.off_diagonal_sq_sum_ref(z1, z2, scale=N))
    got_fused = float(xops.off_diagonal_sq_sum(z1, z2, scale=float(N)))
    got_gram = float(xops.r_off_gram(z1, z2, scale=float(N)))
    rows.append(fmt_row("equiv/off_diag", 0.0,
                        f"oracle={want_o:.4f};fused_err={abs(got_fused-want_o):.2e};gram_err={abs(got_gram-want_o):.2e}"))

    # interpret-mode kernel wall times (logic check, not TPU perf)
    for name, fn in (
        ("kernel_grouped", jax.jit(lambda a, b: gops.r_sum_kernel(a, b, block_size=B, q=2, scale=N))),
        ("kernel_fourstep", jax.jit(lambda a, b: fops.r_sum_fourstep(a, b, q=2, scale=N))),
        ("kernel_xcorr", jax.jit(lambda a, b: xops.off_diagonal_sq_sum(a, b, scale=float(N)))),
    ):
        us = time_fn(fn, z1, z2, repeats=2)
        rows.append(fmt_row(f"equiv_time/{name}", us, "interpret_mode=true"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
