"""Table 4 / Tables 12-13 analogue: forward-loss and backward-loss node
time, proposed vs baseline, across d — the loss node is where the paper's
O(nd^2) -> O(nd log d) bites.

Wall-clock on this CPU (single device) with n = 128, plus the Pallas-kernel
variants in interpret mode for completeness (interpret mode measures the
kernel *logic*, not TPU speed — compiled FLOP ratios are in
bench_complexity)."""

from __future__ import annotations

import jax

from benchmarks.common import fmt_row, time_fn
from repro.core import losses as L

N = 128
DS = (2048, 4096, 8192)


def run():
    rows = []
    for d in DS:
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        z1 = jax.random.normal(k1, (N, d))
        z2 = jax.random.normal(k2, (N, d))
        key = jax.random.PRNGKey(1)
        arms = {
            "bt_off": L.DecorrConfig(style="bt", reg="off"),
            "bt_sum": L.DecorrConfig(style="bt", reg="sum", q=2),
            "bt_sum_b128": L.DecorrConfig(style="bt", reg="sum", q=2, block_size=128),
            "vic_off": L.DecorrConfig(style="vic", reg="off"),
            "vic_sum": L.DecorrConfig(style="vic", reg="sum", q=1),
        }
        base = {}
        for name, cfg in arms.items():
            fwd = jax.jit(lambda a, b: L.ssl_loss(a, b, cfg, key)[0])
            bwd = jax.jit(jax.grad(lambda a, b: L.ssl_loss(a, b, cfg, key)[0], argnums=(0, 1)))
            us_f = time_fn(fwd, z1, z2, repeats=3)
            us_b = time_fn(bwd, z1, z2, repeats=3)
            fam = name.split("_")[0]
            if name.endswith("_off"):
                base[fam] = (us_f, us_b)
            sf = base[fam][0] / us_f
            sb = base[fam][1] / us_b
            rows.append(
                fmt_row(
                    f"train_time/{name}/d{d}",
                    us_f + us_b,
                    f"fwd_us={us_f:.0f};bwd_us={us_b:.0f};fwd_speedup={sf:.2f}x;bwd_speedup={sb:.2f}x",
                )
            )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
