"""Default-vs-tuned kernel configs on the ssl-paper shapes.

Runs the dry-mode (compiled-HLO) tuner over every kernel shape reached from
the paper's SSL setting (batch x projector width), then writes
``BENCH_tune.json`` recording default and tuned configs with their compiled
FLOPs/bytes.  The tuner's ``guard_default`` invariant means tuned is never
worse than default on either metric — this file is the perf trajectory's
paper trail.

Env knobs (for CI): BENCH_TUNE_N / BENCH_TUNE_D override the ssl-paper
batch/width; BENCH_TUNE_OUT overrides the output path.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import fmt_row

OUT_PATH = os.environ.get("BENCH_TUNE_OUT", "BENCH_tune.json")


def _shapes() -> list[tuple[int, int]]:
    n_env, d_env = os.environ.get("BENCH_TUNE_N"), os.environ.get("BENCH_TUNE_D")
    if n_env and d_env:
        return [(int(n_env), int(d_env))]
    from repro.tune.cli import arch_shapes

    return arch_shapes("ssl-paper")


# the LM-serving kernel family: the continuous-batching pool shape of the
# serve bench ((slots, max_len, kv_heads, head_dim) on gemma2-2b reduced)
SERVE_JOBS = [("paged_attention", (8, 80, 2, 16))]


def run():
    from repro import tune
    from repro.tune.cli import jobs_for

    rows = []
    report = {"arch": "ssl-paper", "mode": "dry", "kernels": []}
    # persist=False: a reporting run must not mutate the machine's dispatch
    # cache — pre-warming is the CLI pre-tuner's job, not the benchmark's.
    kw = dict(mode="dry", max_candidates=6, persist=False)
    shapes = _shapes()
    for i, (n, d) in enumerate(shapes):
        plans, jobs = jobs_for(n, d, **kw)
        if i == len(shapes) - 1:
            jobs = jobs + SERVE_JOBS  # once, not per ssl width
        results = list(plans)
        for kernel, shape in jobs:
            results.append(tune.tune(kernel, shape, **kw))
        for res in results:
            default = res.candidate_for(res.default)
            tuned = res.candidate_for(res.best)
            assert tuned.cost["flops"] <= default.cost["flops"]
            assert tuned.cost["hbm_bytes"] <= default.cost["hbm_bytes"]
            name = f"tune/{res.kernel}/{'x'.join(map(str, res.shape))}"
            report["kernels"].append(
                {
                    "kernel": res.kernel,
                    "shape": list(res.shape),
                    "backend": res.backend,
                    "default": {"config": default.config, "cost": default.cost},
                    "tuned": {"config": tuned.config, "cost": tuned.cost},
                    "flops_ratio": tuned.cost["flops"] / max(default.cost["flops"], 1.0),
                    "bytes_ratio": tuned.cost["hbm_bytes"] / max(default.cost["hbm_bytes"], 1.0),
                }
            )
            rows.append(
                fmt_row(
                    name,
                    0.0,  # dry mode: ranking is compiled-cost, nothing is executed
                    f"flops={tuned.cost['flops']:.3e};bytes={tuned.cost['hbm_bytes']:.3e};"
                    f"default_flops={default.cost['flops']:.3e};"
                    f"default_bytes={default.cost['hbm_bytes']:.3e}",
                )
            )
    with open(OUT_PATH, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    rows.append(f"# wrote {OUT_PATH} ({len(report['kernels'])} kernel shapes)")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
