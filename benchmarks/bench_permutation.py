"""Tables 5 & 6 analogue: the effect of feature permutation.

Trains the paper's SSL setup (small scale, CPU) with the proposed
regularizer with/without permutation and reports:
  * the normalized baseline regularizer value (Eq. 16) of the learned
    embeddings — Table 6's decorrelation-quality metric,
  * wall-time per step — Table 5's "permutation is negligible" claim.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row
from repro.core.losses import DecorrConfig, normalized_bt_regularizer, normalized_vic_regularizer
from repro.data import SSLDataConfig, ssl_batch
from repro.optim import adamw, warmup_cosine
from repro.train import create_train_state
from repro.train.ssl import SSLModelConfig, embed, init_ssl_params, make_ssl_train_step

MODEL = SSLModelConfig(input_dim=256, backbone_widths=(128,), projector_widths=(128, 128))
DATA = SSLDataConfig(input_dim=256, batch=128, noise=0.05, mask_prob=0.15, jitter=0.1)
STEPS = 150


def _train(loss_cfg: DecorrConfig, seed=0):
    params = init_ssl_params(jax.random.PRNGKey(seed), MODEL)
    opt = adamw(weight_decay=0.0)
    state = create_train_state(params, opt, seed=seed)
    step_fn, _ = make_ssl_train_step(MODEL, loss_cfg, opt, warmup_cosine(2e-3, 10, STEPS))
    step_fn = jax.jit(step_fn)
    # warmup compile
    v1, v2 = ssl_batch(DATA, 0)
    state, _ = step_fn(state, {"view1": jnp.asarray(v1), "view2": jnp.asarray(v2)})
    t0 = time.perf_counter()
    for i in range(1, STEPS):
        v1, v2 = ssl_batch(DATA, i)
        state, _ = step_fn(state, {"view1": jnp.asarray(v1), "view2": jnp.asarray(v2)})
    per_step_us = (time.perf_counter() - t0) / (STEPS - 1) * 1e6
    v1, v2 = ssl_batch(DATA, 10_000)
    z1 = embed(state.params, jnp.asarray(v1))
    z2 = embed(state.params, jnp.asarray(v2))
    return float(normalized_bt_regularizer(z1, z2)), float(normalized_vic_regularizer(z1, z2)), per_step_us


def run():
    rows = []
    arms = {
        "baseline_off": DecorrConfig(style="bt", reg="off", lam=0.01),
        "sum_perm": DecorrConfig(style="bt", reg="sum", q=2, lam=0.01, permute=True),
        "sum_noperm": DecorrConfig(style="bt", reg="sum", q=2, lam=0.01, permute=False),
        "sum_b32_perm": DecorrConfig(style="bt", reg="sum", q=2, block_size=32, lam=0.01, permute=True),
        "sum_b32_noperm": DecorrConfig(style="bt", reg="sum", q=2, block_size=32, lam=0.01, permute=False),
    }
    for name, cfg in arms.items():
        eq16, eq17, us = _train(cfg)
        rows.append(fmt_row(f"permutation/{name}", us, f"norm_bt_eq16={eq16:.4f};norm_vic_eq17={eq17:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
