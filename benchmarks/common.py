"""Benchmark helpers: wall-clock timing + compiled-graph cost extraction."""

from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_hlo


def time_fn(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N wall time in microseconds (jitted fn; blocks on result)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def compiled_costs(fn: Callable, *shape_args) -> Dict[str, float]:
    """Trip-exact flops/bytes of the compiled (single-device) graph."""
    compiled = jax.jit(fn).lower(*shape_args).compile()
    a = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": a.flops,
        "hbm_bytes": a.hbm_bytes,
        "temp_bytes": float(mem.temp_size_in_bytes),
    }


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
