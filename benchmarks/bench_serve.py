"""Serving policies: (embedding path) naive per-request vs dynamic
micro-batching on the ssl-paper reduced config, (LM path) whole-request
``greedy_generate`` vs continuous batching on a mixed-length workload, and
(paged path) dense vs paged KV cache on a length-SKEWED workload — many
short requests sharing a pool sized for the rare long one, the fragmentation
case block tables exist for — (prefix path) the prefix-sharing radix
cache on a shared-prefix fan-out workload: warm requests resume chunked
prefill past the cached pages — and (spec path) self-drafting speculative
decode vs plain paged decode on a decode-heavy workload.  Emits
``BENCH_serve.json`` (p50/p99 latency + throughput per policy, probe health,
probe-vs-oracle agreement, paged peak cache bytes vs the dense pool,
warm-vs-cold prefix TTFT + peak pages, speculative acceptance stats); CI
gates (``benchmarks/compare.py``) that micro-batched >= naive, continuous >=
whole-request (identical tokens), paged == dense tokens with strictly
smaller peak cache bytes, prefix sharing == unshared tokens with strictly
lower warm TTFT and peak pages, speculative == plain tokens at >= plain
tok/s with more than one accepted token per verify step, probes match the
training-path oracle, and no gated ratio regresses >20% against the
committed baseline.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row

# ssl-paper reduced: paper-shaped siamese MLP, sized for a CPU bench run
REDUCED = dict(input_dim=64, backbone=128, d=512)
POLICY = dict(max_batch=64, max_wait_ms=2.0)
N_REQUESTS = 512
# LM continuous batching: small attention arch, mixed-length closed loop
LM = dict(arch="gemma2-2b", n_requests=32, slots=8)
# paged KV: skewed length mix (mostly short prompts, a rare long one dictates
# the dense pool's max_len), page size pinned for a reproducible layout
PAGED = dict(
    n_requests=24,
    prompt_lens=(4, 6, 8, 40),
    new_tokens=(4, 12, 20),
    slots=8,
    page_size=16,
    prefill_chunk=16,
)
# prefix sharing: 2 long prefixes fanned out to 7 requests each; page 16 with
# chunk 8 and a 92-token prefix puts warm hits mid-page (h=88), so the
# copy-on-write path runs, not just whole-page binding
PREFIX = dict(
    n_prefixes=2,
    fan_out=7,
    prefix_len=92,
    tail_lens=(3, 5, 9),
    new_tokens=(32, 48),
    slots=4,
    page_size=16,
    prefill_chunk=8,
)
# speculative decoding: decode-heavy mix (short prompts, long generations) so
# verify steps dominate and the n-gram drafter has context to look up; greedy
# from a random-init net falls into repetitive cycles the drafter catches
SPECDEC = dict(
    n_requests=32,
    prompt_lens=(4, 6, 8),
    new_tokens=(24, 32),
    slots=8,
    page_size=16,
    draft_k=4,
    ngram_max=3,
    ngram_min=1,
)
# serving fabric: N threaded replicas behind the router (XLA releases the GIL
# during device execution, so scaling needs real cores — the scaling target is
# hardware-aware), plus the synchronous kill-one-replica failover leg and the
# feature-sharded tp forward vs its single-device oracle
FABRIC = dict(
    replicas=2,
    n_requests=16,
    prompt_lens=(4, 8, 14),
    new_tokens=(8, 16),
    n_embed=4,
    slots=4,
    page_size=16,
    tp=2,
)


def run():
    from repro.decorr import probe_metrics
    from repro.decorr.config import DecorrConfig
    from repro.serve import BucketPolicy, DecorrProbe, LoadConfig, ServeEngine, bucket_sizes
    from repro.serve.loadgen import compare_policies
    from repro.train.ssl import SSLModelConfig, init_ssl_params

    model = SSLModelConfig(
        input_dim=REDUCED["input_dim"],
        backbone_widths=(REDUCED["backbone"],),
        projector_widths=(REDUCED["d"], REDUCED["d"]),
    )
    params = init_ssl_params(jax.random.PRNGKey(0), model)
    policy = BucketPolicy(**POLICY)
    probe_cfg = DecorrConfig(style="vic", reg="sum", q=2)

    load = LoadConfig(n_requests=N_REQUESTS, input_dim=REDUCED["input_dim"])
    report = compare_policies(
        lambda: ServeEngine(model, params, policy=policy),
        load,
        policy,
        probe_fn=lambda: DecorrProbe(probe_cfg),
    )

    # probe-vs-oracle agreement on one served batch (acceptance criterion:
    # the online probe equals the training-path computation to tolerance)
    n = bucket_sizes(policy)[-1]
    x = np.random.default_rng(1).standard_normal((n, REDUCED["input_dim"])).astype(np.float32)
    engine = ServeEngine(model, params, policy=policy)
    z = engine.encode(x)
    key = jax.random.fold_in(jax.random.PRNGKey(0), jnp.uint32(0))
    served = DecorrProbe(probe_cfg, sample_rows=n)
    served.observe(np.asarray(z))
    oracle = {k: float(v) for k, v in probe_metrics(z, cfg=probe_cfg, perm_key=key).items()}
    probe_err = max(
        abs(served.metrics()[f"decorr_{k}"] - v) / max(abs(v), 1e-6)
        for k, v in oracle.items()
    )

    lm_report = _run_lm_continuous()
    paged_report = _run_paged()
    prefix_report = _run_prefix()
    spec_report = _run_spec()
    obs_report = _run_obs_overhead()
    perf_report = _run_perf()
    fabric_report = _run_fabric()

    out = {
        "config": {
            **REDUCED,
            **POLICY,
            "n_requests": N_REQUESTS,
            "buckets": list(bucket_sizes(policy)),
            "lm": LM,
            "paged": PAGED,
            "prefix": PREFIX,
            "spec": SPECDEC,
            "fabric": FABRIC,
        },
        "naive": report["naive"],
        "microbatch": report["microbatch"],
        "probe": {
            "oracle_rel_err": probe_err,
            **{k: v for k, v in report["service_metrics"].items() if k.startswith("decorr_")},
        },
        "gate": report["gate"],
        "lm": lm_report,
        "paged": paged_report,
        "prefix": prefix_report,
        "spec": spec_report,
        "obs": obs_report,
        "perf": perf_report,
        "fabric": fabric_report,
    }
    with open(os.path.join(os.getcwd(), "BENCH_serve.json"), "w") as f:
        json.dump(out, f, indent=2, sort_keys=True, default=float)

    rows = []
    for name in ("naive", "microbatch"):
        r = report[name]
        rows.append(fmt_row(
            f"serve/{name}", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']:.2f};throughput_rps={r['throughput_rps']:.0f}",
        ))
    rows.append(fmt_row(
        "serve/gate_microbatch_beats_naive", 0.0,
        f"speedup={report['gate']['speedup']:.2f}x;"
        f"ok={report['gate']['microbatch_beats_naive']};"
        f"probe_oracle_rel_err={probe_err:.2e}",
    ))
    for name in ("whole_request", "continuous"):
        r = lm_report[name]
        rows.append(fmt_row(
            f"serve/lm_{name}", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']:.2f};tok_per_s={r['tok_per_s']:.0f}",
        ))
    g = lm_report["gate"]
    rows.append(fmt_row(
        "serve/gate_continuous_beats_whole_request", 0.0,
        f"speedup={g['speedup']:.2f}x;ok={g['continuous_beats_whole_request']};"
        f"token_mismatches={g['token_mismatches']:.0f};"
        f"probe_oracle_rel_err={g.get('probe_oracle_rel_err', float('nan')):.2e};"
        f"occupancy={lm_report['service_metrics']['slots_occupancy']:.2f}",
    ))
    for name in ("dense", "paged"):
        r = paged_report[name]
        cache = r.get("cache_bytes", r.get("paged_peak_cache_bytes", 0.0))
        rows.append(fmt_row(
            f"serve/paged_{name}", r["p50_ms"] * 1e3,
            f"tok_per_s={r['tok_per_s']:.0f};cache_bytes={cache:.0f}",
        ))
    pg = paged_report["gate"]
    rows.append(fmt_row(
        "serve/gate_paged_peak_lt_dense", 0.0,
        f"ok={pg['paged_peak_lt_dense']};bytes_ratio={pg['peak_cache_bytes_ratio']:.3f};"
        f"token_mismatches={pg['token_mismatches']:.0f};"
        f"tok_per_s_ratio={pg['tok_per_s_ratio']:.2f}",
    ))
    for name in ("unshared", "shared"):
        r = prefix_report[name]
        rows.append(fmt_row(
            f"serve/prefix_{name}", r["warm_ttft_p50_ms"] * 1e3,
            f"tok_per_s={r['tok_per_s']:.0f};peak_pages={r['peak_pages']:.0f}",
        ))
    xg = prefix_report["gate"]
    rows.append(fmt_row(
        "serve/gate_prefix_sharing", 0.0,
        f"ok={xg['warm_ttft_lt_unshared'] and xg['peak_pages_lt_unshared']};"
        f"hit_rate={xg['prefix_hit_rate']:.2f};"
        f"warm_ttft_ratio={xg['warm_ttft_ratio']:.3f};"
        f"peak_pages_ratio={xg['peak_pages_ratio']:.3f};"
        f"token_mismatches={xg['token_mismatches']:.0f};"
        f"probe_oracle_rel_err={xg.get('probe_oracle_rel_err', float('nan')):.2e}",
    ))
    for name in ("plain", "speculative"):
        r = spec_report[name]
        rows.append(fmt_row(
            f"serve/spec_{name}", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']:.2f};tok_per_s={r['tok_per_s']:.0f}",
        ))
    sg = spec_report["gate"]
    rows.append(fmt_row(
        "serve/gate_speculative", 0.0,
        f"ok={sg['spec_beats_plain'] and sg['accepted_tokens_per_step'] > 1};"
        f"tok_per_s_ratio={sg['tok_per_s_ratio']:.2f};"
        f"accepted_per_step={sg['accepted_tokens_per_step']:.2f};"
        f"tokens_per_lane={sg['tokens_per_lane']:.2f};"
        f"hit_rate={sg['draft_hit_rate']:.2f};"
        f"token_mismatches={sg['token_mismatches']:.0f}",
    ))
    for name in ("off", "on"):
        r = obs_report[name]
        rows.append(fmt_row(
            f"serve/obs_{name}", r["p50_ms"] * 1e3,
            f"tok_per_s={r['tok_per_s']:.0f}",
        ))
    og = obs_report["gate"]
    rows.append(fmt_row(
        "serve/gate_obs_overhead", 0.0,
        f"ok={og['overhead_ok']};tok_per_s_ratio={og['tok_per_s_ratio']:.3f}",
    ))
    pf = perf_report["gate"]
    rows.append(fmt_row(
        "serve/gate_perf_attribution", 0.0,
        f"ok={pf['has_required'] and pf['nonzero_samples'] and pf['utilization_ok']};"
        f"executables={pf['n_executables']};"
        f"max_disagreement={pf['max_disagreement']:.1f}",
    ))
    for name in ("single", "multi"):
        r = fabric_report[name]
        rows.append(fmt_row(
            f"serve/fabric_{name}", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']:.2f};tok_per_s={r['tok_per_s']:.0f}",
        ))
    fg = fabric_report["gate"]
    rows.append(fmt_row(
        "serve/gate_fabric", 0.0,
        f"ok={fg['scaling_ok'] and fg['token_mismatches'] == 0 and fg['requeue_token_mismatches'] == 0};"
        f"scaling_x={fg['scaling_x']:.2f};target={fg['scaling_target']:.2f};"
        f"cores={fg['cores']:.0f};"
        f"requeued={fg['requeued']:.0f};"
        f"token_mismatches={fg['token_mismatches']:.0f};"
        f"requeue_token_mismatches={fg['requeue_token_mismatches']:.0f};"
        f"tp_rel_err={fg['tp_rel_err']:.2e}",
    ))
    return rows


def _run_lm_continuous():
    """Whole-request vs continuous batching on a mixed-length LM workload
    (the acceptance gate: interleaving must win throughput without changing
    a single emitted token, with the in-flight probe oracle-exact)."""
    from repro.configs import get_config
    from repro.decorr.config import DecorrConfig
    from repro.models import init_params
    from repro.serve.loadgen import LMLoadConfig, compare_lm_policies

    from repro.serve import DecorrProbe

    cfg = get_config(LM["arch"]).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    load = LMLoadConfig(n_requests=LM["n_requests"])
    report = compare_lm_policies(
        cfg,
        params,
        load,
        n_slots=LM["slots"],
        probe_fn=lambda: DecorrProbe(DecorrConfig(style="vic", reg="sum", q=2)),
        record_probe_rows=True,
    )
    keep = ("whole_request", "continuous", "gate")
    out = {k: report[k] for k in keep}
    out["service_metrics"] = {
        k: v
        for k, v in report["service_metrics"].items()
        if k.startswith(("slots_", "ttft_", "decorr_")) or k in ("tok_per_s", "tokens_total")
    }
    return out


def _run_paged():
    """Dense vs paged continuous batching at a skewed length mix (the
    acceptance gate: identical greedy tokens, strictly lower peak cache
    bytes than the dense pool's permanent reservation; a chunked-prefill
    paged run reports its tokens + TTFT alongside)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.loadgen import LMLoadConfig, compare_paged_dense

    cfg = get_config(LM["arch"]).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    load = LMLoadConfig(
        n_requests=PAGED["n_requests"],
        prompt_lens=PAGED["prompt_lens"],
        new_tokens=PAGED["new_tokens"],
    )
    return compare_paged_dense(
        cfg,
        params,
        load,
        n_slots=PAGED["slots"],
        page_size=PAGED["page_size"],
        prefill_chunk=PAGED["prefill_chunk"],
    )


def _run_prefix():
    """Prefix sharing on vs off over the same paged chunk-all engine on a
    shared-prefix fan-out workload (the acceptance gate: bit-identical
    tokens, warm-phase TTFT and peak pool pages both strictly below the
    unshared run, with the in-flight probe still oracle-exact under page
    sharing)."""
    from repro.configs import get_config
    from repro.decorr.config import DecorrConfig
    from repro.models import init_params
    from repro.serve import DecorrProbe
    from repro.serve.loadgen import SharedPrefixLoadConfig, compare_prefix_sharing

    cfg = get_config(LM["arch"]).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    load = SharedPrefixLoadConfig(
        n_prefixes=PREFIX["n_prefixes"],
        fan_out=PREFIX["fan_out"],
        prefix_len=PREFIX["prefix_len"],
        tail_lens=PREFIX["tail_lens"],
        new_tokens=PREFIX["new_tokens"],
    )
    return compare_prefix_sharing(
        cfg,
        params,
        load,
        n_slots=PREFIX["slots"],
        page_size=PREFIX["page_size"],
        prefill_chunk=PREFIX["prefill_chunk"],
        probe_fn=lambda: DecorrProbe(DecorrConfig(style="vic", reg="sum", q=2)),
        record_probe_rows=True,
    )


def _run_spec():
    """Plain paged vs self-drafting speculative decode on a decode-heavy
    workload (the acceptance gate: bit-identical greedy tokens, tok/s at
    least the plain paged run's, and more than one token emitted per verify
    step — the speculation actually pays for its lane-batched forward)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.loadgen import LMLoadConfig, compare_speculative

    cfg = get_config(LM["arch"]).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    load = LMLoadConfig(
        n_requests=SPECDEC["n_requests"],
        prompt_lens=SPECDEC["prompt_lens"],
        new_tokens=SPECDEC["new_tokens"],
    )
    return compare_speculative(
        cfg,
        params,
        load,
        n_slots=SPECDEC["slots"],
        page_size=SPECDEC["page_size"],
        draft_k=SPECDEC["draft_k"],
        spec_ngram_max=SPECDEC["ngram_max"],
        spec_ngram_min=SPECDEC["ngram_min"],
    )


def _run_obs_overhead():
    """Telemetry on vs off on the same continuous-batching workload (the
    acceptance gate: the always-on tracer/recorder/registry path must keep
    >= 95% of the telemetry-off tok/s — observability that taxes the decode
    loop does not ship)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.obs import Obs
    from repro.serve import ContinuousLMEngine, LMService
    from repro.serve.loadgen import LMLoadConfig, run_continuous

    cfg = get_config(LM["arch"]).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    load = LMLoadConfig(n_requests=LM["n_requests"])

    def measure(obs):
        engine = ContinuousLMEngine(
            cfg, params, n_slots=LM["slots"],
            max_len=max(load.max_request_len + 8, 32),
            max_prompt_len=max(load.prompt_lens),
        )
        svc = LMService(engine, obs=obs)
        best = None
        for _ in range(2):  # first pass pays compile; keep the best of two
            summary, _ = run_continuous(svc, load)
            if best is None or summary["tok_per_s"] > best["tok_per_s"]:
                best = summary
        return best

    off = measure(Obs.disabled())
    on = measure(Obs())
    ratio = on["tok_per_s"] / max(off["tok_per_s"], 1e-9)
    return {
        "on": on,
        "off": off,
        "gate": {"tok_per_s_ratio": ratio, "overhead_ok": ratio >= 0.95},
    }


def _run_perf():
    """Per-executable attribution over both serving paths (the acceptance
    gate: every compiled executable the workload exercises shows nonzero
    wall-time samples AND a roofline-utilization value in (0, 1] from the
    measured-time x analytic-HLO-cost join).  One shared ``Obs`` so the
    embedding buckets, the LM prefill/decode/chunk executables and the
    probe land in one attribution table — what the ``/perf`` endpoint and
    the analytic-vs-measured disagreement metric read."""
    from repro.configs import get_config
    from repro.decorr.config import DecorrConfig
    from repro.models import init_params
    from repro.obs import Obs
    from repro.serve import (
        BucketPolicy,
        ContinuousLMEngine,
        DecorrProbe,
        LMService,
        ServeEngine,
    )
    from repro.serve.service import EmbeddingService
    from repro.serve.loadgen import LMLoadConfig, run_continuous
    from repro.train.ssl import SSLModelConfig, init_ssl_params

    obs = Obs()
    probe_cfg = DecorrConfig(style="vic", reg="sum", q=2)

    # embedding leg: warm the bucket ladder, then serve a closed-loop burst
    model = SSLModelConfig(
        input_dim=REDUCED["input_dim"],
        backbone_widths=(REDUCED["backbone"],),
        projector_widths=(REDUCED["d"], REDUCED["d"]),
    )
    ssl_params = init_ssl_params(jax.random.PRNGKey(0), model)
    policy = BucketPolicy(**POLICY)
    svc = EmbeddingService(
        ServeEngine(model, ssl_params, policy=policy),
        probe=DecorrProbe(probe_cfg),
        obs=obs,
    ).warmup()
    rng = np.random.default_rng(2)
    futs = [
        svc.submit(rng.standard_normal(REDUCED["input_dim"]).astype(np.float32))
        for _ in range(32)
    ]
    while svc.run_pending():
        pass
    for f in futs:
        f.result(timeout=30)

    # LM leg: paged + chunked prefill so the skewed mix exercises the
    # per-bucket prefills, the chunk step AND the batched decode tick
    cfg = get_config(LM["arch"]).reduced()
    lm_params = init_params(jax.random.PRNGKey(0), cfg)
    load = LMLoadConfig(
        n_requests=PAGED["n_requests"],
        prompt_lens=PAGED["prompt_lens"],
        new_tokens=PAGED["new_tokens"],
    )
    engine = ContinuousLMEngine(
        cfg, lm_params, n_slots=PAGED["slots"],
        max_len=max(load.max_request_len + 8, 32),
        max_prompt_len=max(load.prompt_lens),
        paged=True, page_size=PAGED["page_size"],
        prefill_chunk=PAGED["prefill_chunk"],
    )
    lm_svc = LMService(engine, probe=DecorrProbe(probe_cfg), obs=obs)
    summary, _ = run_continuous(lm_svc, load)

    rows = obs.perf.snapshot()
    names = {r["executable"] for r in rows}
    utils = {r["executable"]: r.get("roofline_utilization") for r in rows}
    disagreements = [r["disagreement"] for r in rows if r.get("disagreement")]
    gate = {
        "n_executables": len(rows),
        "has_required": (
            {"decode_step", "chunk_prefill", "probe_update"} <= names
            and any(n.startswith("prefill_b") for n in names)
            and any(n.startswith("embed_b") for n in names)
        ),
        "nonzero_samples": bool(rows) and all(
            r["calls"] > 0 and r["total_s"] > 0 for r in rows
        ),
        "utilization_ok": bool(rows) and all(
            u is not None and 0.0 < u <= 1.0 for u in utils.values()
        ),
        "max_disagreement": max(disagreements, default=0.0),
    }
    return {
        "executables": {r["executable"]: r for r in rows},
        "lm_tok_per_s": summary["tok_per_s"],
        "gate": gate,
    }


def _tp_oracle_subprocess(tp: int) -> float:
    """The tp-forward oracle needs > 1 device but this process already
    imported jax single-device, so force host devices in a child and read
    the error back (the test-suite pattern, see test_serve_fabric)."""
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={tp}"
        import jax
        from repro.serve.loadgen import tp_oracle_err
        from repro.train.ssl import SSLModelConfig, init_ssl_params

        model = SSLModelConfig(input_dim=24, backbone_widths=(32,),
                               projector_widths=(48, 48))
        params = init_ssl_params(jax.random.PRNGKey(0), model)
        print(tp_oracle_err(model, params, tp={tp}))
        """
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=420,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"tp oracle subprocess failed:\n{proc.stderr[-3000:]}")
    return float(proc.stdout.strip().splitlines()[-1])


def _run_fabric():
    """N threaded replicas behind the router vs one, the kill-one-replica
    failover leg, and the tp-forward oracle (the acceptance gates:
    route-independent AND requeue-surviving token identity — both must be
    bit-exact — plus aggregate tok/s scaling against a hardware-aware
    target: replica threads only overlap on real cores, so a 1-core runner
    gates at ~parity while multi-core runners must show the scaling win)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve.loadgen import FabricLoadConfig, LMLoadConfig, compare_fabric
    from repro.train.ssl import SSLModelConfig, init_ssl_params

    cfg = get_config(LM["arch"]).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    embed_model = SSLModelConfig(
        input_dim=24, backbone_widths=(32,), projector_widths=(48, 48)
    )
    embed_params = init_ssl_params(jax.random.PRNGKey(1), embed_model)
    load = FabricLoadConfig(
        lm=LMLoadConfig(
            n_requests=FABRIC["n_requests"],
            prompt_lens=FABRIC["prompt_lens"],
            new_tokens=FABRIC["new_tokens"],
        ),
        n_embed=FABRIC["n_embed"],
        input_dim=24,
    )
    report = compare_fabric(
        cfg, params, load,
        replicas=FABRIC["replicas"],
        n_slots=FABRIC["slots"],
        page_size=FABRIC["page_size"],
        embed_cfg=embed_model,
        embed_params=embed_params,
    )
    cores = float(os.cpu_count() or 1)
    scaling_target = 1.6 if cores >= 2 else 1.05
    tp_err = _tp_oracle_subprocess(FABRIC["tp"])
    report["gate"].update(
        cores=cores,
        scaling_target=scaling_target,
        scaling_ok=report["gate"]["scaling_x"] >= scaling_target,
        tp_rel_err=tp_err,
        tp=float(FABRIC["tp"]),
    )
    # the labelled per-replica gauges don't serialize as flat floats; keep
    # the flat subset in the JSON report
    report["fabric_metrics"] = {
        k: v for k, v in report["fabric_metrics"].items()
        if isinstance(v, (int, float))
    }
    return report


if __name__ == "__main__":
    print("\n".join(run()))
