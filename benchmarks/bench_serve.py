"""Serving policies: naive per-request vs dynamic micro-batching, on the
ssl-paper reduced config.  Emits ``BENCH_serve.json`` (p50/p99 latency +
throughput per policy, probe health, probe-vs-oracle agreement); CI gates
that micro-batched throughput >= naive per-request throughput.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row

# ssl-paper reduced: paper-shaped siamese MLP, sized for a CPU bench run
REDUCED = dict(input_dim=64, backbone=128, d=512)
POLICY = dict(max_batch=64, max_wait_ms=2.0)
N_REQUESTS = 512


def run():
    from repro.decorr import probe_metrics
    from repro.decorr.config import DecorrConfig
    from repro.serve import BucketPolicy, DecorrProbe, LoadConfig, ServeEngine, bucket_sizes
    from repro.serve.loadgen import compare_policies
    from repro.train.ssl import SSLModelConfig, init_ssl_params

    model = SSLModelConfig(
        input_dim=REDUCED["input_dim"],
        backbone_widths=(REDUCED["backbone"],),
        projector_widths=(REDUCED["d"], REDUCED["d"]),
    )
    params = init_ssl_params(jax.random.PRNGKey(0), model)
    policy = BucketPolicy(**POLICY)
    probe_cfg = DecorrConfig(style="vic", reg="sum", q=2)

    load = LoadConfig(n_requests=N_REQUESTS, input_dim=REDUCED["input_dim"])
    report = compare_policies(
        lambda: ServeEngine(model, params, policy=policy),
        load,
        policy,
        probe_fn=lambda: DecorrProbe(probe_cfg),
    )

    # probe-vs-oracle agreement on one served batch (acceptance criterion:
    # the online probe equals the training-path computation to tolerance)
    n = bucket_sizes(policy)[-1]
    x = np.random.default_rng(1).standard_normal((n, REDUCED["input_dim"])).astype(np.float32)
    engine = ServeEngine(model, params, policy=policy)
    z = engine.encode(x)
    key = jax.random.fold_in(jax.random.PRNGKey(0), jnp.uint32(0))
    served = DecorrProbe(probe_cfg, sample_rows=n)
    served.observe(np.asarray(z))
    oracle = {k: float(v) for k, v in probe_metrics(z, cfg=probe_cfg, perm_key=key).items()}
    probe_err = max(
        abs(served.metrics()[f"decorr_{k}"] - v) / max(abs(v), 1e-6)
        for k, v in oracle.items()
    )

    out = {
        "config": {
            **REDUCED,
            **POLICY,
            "n_requests": N_REQUESTS,
            "buckets": list(bucket_sizes(policy)),
        },
        "naive": report["naive"],
        "microbatch": report["microbatch"],
        "probe": {
            "oracle_rel_err": probe_err,
            **{k: v for k, v in report["service_metrics"].items() if k.startswith("decorr_")},
        },
        "gate": report["gate"],
    }
    with open(os.path.join(os.getcwd(), "BENCH_serve.json"), "w") as f:
        json.dump(out, f, indent=2, sort_keys=True, default=float)

    rows = []
    for name in ("naive", "microbatch"):
        r = report[name]
        rows.append(fmt_row(
            f"serve/{name}", r["p50_ms"] * 1e3,
            f"p99_ms={r['p99_ms']:.2f};throughput_rps={r['throughput_rps']:.0f}",
        ))
    rows.append(fmt_row(
        "serve/gate_microbatch_beats_naive", 0.0,
        f"speedup={report['gate']['speedup']:.2f}x;"
        f"ok={report['gate']['microbatch_beats_naive']};"
        f"probe_oracle_rel_err={probe_err:.2e}",
    ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
