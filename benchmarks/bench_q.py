"""Table 11 analogue: the effect of q in {1, 2} for BT-style and
VICReg-style regularizers (small-scale training; decorrelation quality via
the baselines' own normalized metrics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_row
from repro.core.losses import DecorrConfig, normalized_bt_regularizer
from repro.data import SSLDataConfig, ssl_batch
from repro.optim import adamw, warmup_cosine
from repro.train import create_train_state
from repro.train.ssl import SSLModelConfig, embed, init_ssl_params, make_ssl_train_step

MODEL = SSLModelConfig(input_dim=256, backbone_widths=(128,), projector_widths=(128, 128))
DATA = SSLDataConfig(input_dim=256, batch=128)
STEPS = 120


def _train(cfg: DecorrConfig):
    params = init_ssl_params(jax.random.PRNGKey(0), MODEL)
    opt = adamw(weight_decay=0.0)
    state = create_train_state(params, opt)
    step_fn, _ = make_ssl_train_step(MODEL, cfg, opt, warmup_cosine(2e-3, 10, STEPS))
    step_fn = jax.jit(step_fn)
    for i in range(STEPS):
        v1, v2 = ssl_batch(DATA, i)
        state, m = step_fn(state, {"view1": jnp.asarray(v1), "view2": jnp.asarray(v2)})
    v1, v2 = ssl_batch(DATA, 10_000)
    z1 = embed(state.params, jnp.asarray(v1))
    z2 = embed(state.params, jnp.asarray(v2))
    return float(normalized_bt_regularizer(z1, z2)), float(m[next(k for k in m if k.endswith("loss"))])


def run():
    rows = []
    for style in ("bt", "vic"):
        for q in (1, 2):
            lam = 0.01 if style == "bt" else 1.0
            cfg = (
                DecorrConfig(style="bt", reg="sum", q=q, lam=lam)
                if style == "bt"
                else DecorrConfig(style="vic", reg="sum", q=q, nu=lam)
            )
            eq16, loss = _train(cfg)
            rows.append(fmt_row(f"q_ablation/{style}_q{q}", 0.0, f"norm_bt_eq16={eq16:.4f};final_loss={loss:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
