"""End-to-end SSL pretraining driver (the paper's training setup, scaled to
this container) with the full production envelope: sharded-ready step,
checkpoint/restart, preemption flag, straggler watchdog.

Default config is a ~100M-parameter backbone+projector trained for a few
hundred steps — the assignment's end-to-end driver.  Use --tiny for a
seconds-scale run.

    PYTHONPATH=src python examples/ssl_pretrain.py --tiny
    PYTHONPATH=src python examples/ssl_pretrain.py \
        --steps 300 --ckpt-dir /tmp/ssl_ckpt          # ~100M params
    # kill it mid-run and re-run: it resumes from the newest checkpoint.
    # distributed (shard_map over all local devices; see docs/distributed.md):
    PYTHONPATH=src python examples/ssl_pretrain.py --tiny --distributed global
    PYTHONPATH=src python examples/ssl_pretrain.py --tiny --distributed tp \
        --model-parallel 2
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.losses import DecorrConfig, normalized_bt_regularizer
from repro.data import SSLDataConfig, ssl_batch
from repro.decorr import warmup_tune_cache
from repro.launch.mesh import make_mesh_for_devices
from repro.launch.obs_args import (
    add_obs_args,
    attach_train_step,
    build_train_obs,
    finish_train_obs,
)
from repro.optim import lars, warmup_cosine
from repro.train import LoopConfig, create_train_state, run_training
from repro.train.ssl import (
    SSLModelConfig,
    embed,
    init_ssl_params,
    make_sharded_ssl_train_step,
    make_ssl_train_step,
    shard_ssl_batch,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--d", type=int, default=8192, help="projector width (paper: 8192)")
    ap.add_argument("--block-size", type=int, default=128)
    ap.add_argument("--reg", default="sum", choices=["sum", "off"])
    ap.add_argument("--no-permute", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--preempt-flag", default=None)
    ap.add_argument(
        "--distributed",
        default=None,
        choices=["local", "global", "tp"],
        help="run the step under shard_map over all local devices "
        "(decorr engine mode; default: single-device step)",
    )
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="model-axis size for --distributed tp")
    add_obs_args(ap)
    ap.add_argument(
        "--pretune",
        default="analytic",
        choices=["off", "analytic", "dry", "measure"],
        help="warm the repro.tune cache for the shard-local decorr shapes "
        "before the first step is traced",
    )
    args = ap.parse_args()

    if args.tiny:
        model = SSLModelConfig(input_dim=256, backbone_widths=(128,), projector_widths=(256, 256))
        data = SSLDataConfig(input_dim=256, batch=128)
        args.steps = min(args.steps, 120)
    else:
        # ~100M params: 3072 -> 4096 -> 4096 backbone, d-wide projector
        model = SSLModelConfig(
            input_dim=3072,
            backbone_widths=(4096, 4096),
            projector_widths=(args.d, args.d),
        )
        data = SSLDataConfig(input_dim=3072, batch=args.batch)

    n_params = sum(
        a * b
        for a, b in zip(
            (model.input_dim,) + model.backbone_widths + (model.backbone_widths[-1],) + model.projector_widths[:-1],
            model.backbone_widths + (model.backbone_widths[-1],) + model.projector_widths,
        )
    )
    print(f"[ssl_pretrain] ~{n_params/1e6:.1f}M params, d={model.projector_widths[-1]}, "
          f"batch={data.batch}, reg={args.reg}, permute={not args.no_permute}")

    loss_cfg = DecorrConfig(
        style="bt", reg=args.reg, q=2,
        block_size=args.block_size if args.reg == "sum" else None,
        lam=2.0**-10, permute=not args.no_permute,
        distributed=args.distributed or "local",
    )
    params = init_ssl_params(jax.random.PRNGKey(0), model)
    opt = lars(weight_decay=1e-4)  # the paper's optimizer
    state = create_train_state(params, opt)
    sched = warmup_cosine(0.2, max(args.steps // 10, 1), args.steps)

    mesh = None
    if args.distributed is not None:
        mesh = make_mesh_for_devices(len(jax.devices()), args.model_parallel)
        print(f"[ssl_pretrain] mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
              f"mode={args.distributed}")
        step_fn, _ = make_sharded_ssl_train_step(model, loss_cfg, opt, sched, mesh)
    else:
        step_fn, _ = make_ssl_train_step(model, loss_cfg, opt, sched)

    if args.pretune != "off":
        # warm the kernel-config cache for the SHARD-LOCAL shapes so the
        # first jitted step doesn't pay the search (ROADMAP open item).
        t_tune = time.time()
        n_jobs = len(warmup_tune_cache(
            data.batch, model.projector_widths[-1], loss_cfg,
            mesh=mesh, mode=args.pretune,
        ))
        print(f"[ssl_pretrain] pre-tuned {n_jobs} kernel shapes "
              f"({args.pretune}, {time.time()-t_tune:.1f}s)")
    step_fn = jax.jit(step_fn)

    def batch_fn(step):
        v1, v2 = ssl_batch(data, step)
        b = {"view1": jnp.asarray(v1), "view2": jnp.asarray(v2)}
        return shard_ssl_batch(b, mesh) if mesh is not None else b

    t0 = time.time()

    def log_fn(step, m):
        loss_key = next(k for k in m if k.endswith("loss"))
        print(f"  step {step:5d}  loss={m[loss_key]:10.4f}  "
              f"({(time.time()-t0):6.1f}s, stragglers={m.get('stragglers', 0)})")

    lcfg = LoopConfig(
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_interval=max(args.steps // 6, 10),
        log_interval=max(args.steps // 15, 1),
        preempt_flag=args.preempt_flag,
    )
    obs = build_train_obs(args)
    monitor = None
    if obs is not None:
        from repro.obs import DecorrHealthMonitor

        # probe the projector output of view1 — the matrix the decorrelation
        # objective acts on — for collapse / relaxation-gap health
        monitor = DecorrHealthMonitor(lambda params, batch: embed(params, batch["view1"]))
        attach_train_step(obs, step_fn, state, batch_fn(0))
    state = run_training(
        state, step_fn, batch_fn, lcfg, log_fn=log_fn,
        registry=obs.registry if obs is not None else None,
        monitor=monitor,
        perf=obs.perf if obs is not None else None,
    )
    finish_train_obs(args, obs)

    v1, v2 = ssl_batch(data, 10_000)
    q16 = normalized_bt_regularizer(
        embed(state.params, jnp.asarray(v1)), embed(state.params, jnp.asarray(v2))
    )
    print(f"[ssl_pretrain] final step={int(state.step)}  "
          f"normalized R_off (Eq.16) = {float(q16):.4f}  total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
