"""Quickstart: the paper's regularizer in five minutes.

    PYTHONPATH=src python examples/quickstart.py

1. computes R_sum via FFT and shows it matches the O(nd^2) matrix route,
2. shows the FLOP asymptotics (O(nd log d) vs O(nd^2)) on compiled graphs,
3. trains a small Barlow Twins-style model with the proposed loss and
   watches the baseline's own decorrelation metric (Eq. 16) drop.
"""

import jax
import jax.numpy as jnp

from repro.core import losses as L
from repro.core import regularizers as regs
from repro.core import sumvec as sv
from repro.data import SSLDataConfig, ssl_batch
from repro.optim import adamw, warmup_cosine
from repro.train import create_train_state
from repro.train.ssl import SSLModelConfig, embed, init_ssl_params, make_ssl_train_step


def main():
    # --- 1. the identity (Eq. 12) ------------------------------------------
    n, d = 64, 256
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    z1, z2 = jax.random.normal(k1, (n, d)), jax.random.normal(k2, (n, d))
    c = regs.cross_correlation_matrix(z1, z2, scale=n)
    via_fft = sv.sumvec_fft(z1, z2, scale=float(n))
    via_mat = sv.sumvec_from_matrix(c)
    print(f"[1] sumvec FFT vs matrix route: max|diff| = "
          f"{float(jnp.max(jnp.abs(via_fft - via_mat))):.2e}  (O(nd log d) vs O(nd^2))")

    # --- 2. compiled FLOPs --------------------------------------------------
    from repro.launch.hlo_cost import analyze_hlo

    def flops_of(fn):
        comp = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((256, 4096), jnp.float32),
            jax.ShapeDtypeStruct((256, 4096), jnp.float32),
        ).compile()
        return analyze_hlo(comp.as_text()).flops

    f_off = flops_of(lambda a, b: regs.r_off(regs.cross_correlation_matrix(a, b, scale=256)))
    f_sum = flops_of(lambda a, b: regs.r_sum(a, b, q=2, scale=256.0))
    print(f"[2] compiled FLOPs at n=256, d=4096:  R_off={f_off:.2e}  "
          f"R_sum={f_sum:.2e}  ({f_off/max(f_sum,1):.0f}x fewer)")

    # --- 3. train with the proposed loss ------------------------------------
    model = SSLModelConfig(input_dim=256, backbone_widths=(128,), projector_widths=(128, 128))
    data = SSLDataConfig(input_dim=256, batch=128)
    loss_cfg = L.DecorrConfig(style="bt", reg="sum", q=2, lam=0.01, permute=True)
    params = init_ssl_params(jax.random.PRNGKey(1), model)
    opt = adamw(weight_decay=0.0)
    state = create_train_state(params, opt)
    step_fn, _ = make_ssl_train_step(model, loss_cfg, opt, warmup_cosine(2e-3, 10, 200))
    step_fn = jax.jit(step_fn)
    print("[3] training Barlow Twins-style with R_sum (+ feature permutation):")
    for i in range(200):
        v1, v2 = ssl_batch(data, i)
        state, m = step_fn(state, {"view1": jnp.asarray(v1), "view2": jnp.asarray(v2)})
        if (i + 1) % 50 == 0:
            v1e, v2e = ssl_batch(data, 10_000)
            q16 = L.normalized_bt_regularizer(embed(state.params, jnp.asarray(v1e)),
                                              embed(state.params, jnp.asarray(v2e)))
            print(f"    step {i+1:4d}  loss={float(m['bt_loss']):8.4f}  "
                  f"normalized R_off (Eq.16)={float(q16):.4f}")
    print("done — the Eq.16 metric (what Barlow Twins itself optimizes) drops"
          " even though we never materialized a d x d matrix.")


if __name__ == "__main__":
    main()
