"""The framework feature: the paper's decorrelation as an auxiliary loss on
an assigned LM architecture's hidden states.

Trains two reduced CodeQwen models — with and without the VICReg-style
R_sum aux loss — and compares (a) LM cross-entropy and (b) the hidden-state
feature-correlation metric (Eq. 16 applied to hidden states).

    PYTHONPATH=src python examples/lm_decorrelation.py --steps 120
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.decorrelation import LMDecorrConfig
from repro.core.losses import DecorrConfig, normalized_bt_regularizer
from repro.data import LMDataConfig, lm_batch
from repro.models import forward, init_params
from repro.optim import adamw, warmup_cosine
from repro.train import create_train_state, make_train_step


def run(arch: str, enabled: bool, steps: int, seed: int = 0):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg,
        decorr=LMDecorrConfig(
            enabled=enabled,
            decorr=DecorrConfig(style="vic", reg="sum", q=2, block_size=None),
            mu=1.0,
            nu=2.0,
            tokens_per_seq=16,
        ),
    )
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw(weight_decay=0.0)
    state = create_train_state(params, opt, seed=seed)
    step_fn = jax.jit(make_train_step(cfg, opt, warmup_cosine(3e-3, 10, steps)))
    dcfg = LMDataConfig(vocab_size=cfg.vocab_size, batch=8, seq_len=32, seed=seed)
    for i in range(steps):
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()})
    out = forward(state.params, cfg, tokens=jnp.asarray(lm_batch(dcfg, 99_999)["tokens"]))
    h = out.hidden.reshape(-1, cfg.d_model)
    corr = float(normalized_bt_regularizer(h, h + 0.0))
    return float(m["ce"]), corr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    t0 = time.time()
    ce_off, corr_off = run(args.arch, enabled=False, steps=args.steps)
    ce_on, corr_on = run(args.arch, enabled=True, steps=args.steps)
    print(f"arch={args.arch} (reduced), {args.steps} steps each, {time.time()-t0:.1f}s total")
    print(f"  without decorr aux:  ce={ce_off:.4f}  hidden feature corr (Eq.16) = {corr_off:.4f}")
    print(f"  with    decorr aux:  ce={ce_on:.4f}  hidden feature corr (Eq.16) = {corr_on:.4f}")
    print(f"  -> correlation reduced {corr_off/max(corr_on,1e-9):.1f}x; "
          f"CE within {abs(ce_on-ce_off)/ce_off*100:.1f}% of the plain run")


if __name__ == "__main__":
    main()
