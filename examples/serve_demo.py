"""Batched serving demo: prefill a batch of prompts, then greedy-decode —
exercises the same serve_step the decode_* dry-run shapes lower, on a
reduced config.  Prompt construction and the warmup-then-time loop are the
shared ``repro.serve.common`` helpers (also used by launch/serve.py and the
serve CLI).

    PYTHONPATH=src python examples/serve_demo.py --arch rwkv6-3b
    PYTHONPATH=src python examples/serve_demo.py --arch musicgen-large
"""

import argparse

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serve.common import make_prompt, timed_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = make_prompt(cfg, jax.random.PRNGKey(1), args.batch, args.prompt_len)

    # timed_generate warms (compiles prefill + decode at the same cache
    # shapes) before timing — the old inline warmup recompiled on the real
    # call because its max_len differed.
    out, stats = timed_generate(params, cfg, prompt, args.new_tokens)
    print(f"[serve] {cfg.name} (reduced): batch={args.batch} prompt={args.prompt_len} "
          f"-> {args.new_tokens} new tokens in {stats['seconds']:.2f}s "
          f"({stats['tok_per_s']:.1f} tok/s)")
    print("sample:", out[0].tolist()[:12])


if __name__ == "__main__":
    main()
