"""Batched serving demo: prefill a batch of prompts, then greedy-decode —
exercises the same serve_step the decode_* dry-run shapes lower, on a
reduced config.

    PYTHONPATH=src python examples/serve_demo.py --arch rwkv6-3b
    PYTHONPATH=src python examples/serve_demo.py --arch musicgen-large
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.train.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    key = jax.random.PRNGKey(1)
    if cfg.frontend == "audio_codes":
        prompt = jax.random.randint(key, (args.batch, args.prompt_len, cfg.n_codebooks), 0, cfg.vocab_size)
    else:
        prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)

    # warm (compile prefill + decode)
    _ = greedy_generate(params, cfg, prompt, 2)
    t0 = time.time()
    out = greedy_generate(params, cfg, prompt, args.new_tokens)
    dt = time.time() - t0
    n = args.batch * args.new_tokens
    print(f"[serve] {cfg.name} (reduced): batch={args.batch} prompt={args.prompt_len} "
          f"-> {args.new_tokens} new tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")
    print("sample:", out[0].tolist()[:12])


if __name__ == "__main__":
    main()
